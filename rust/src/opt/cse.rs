//! Global common-subexpression elimination with einsum-spec
//! canonicalization.
//!
//! The graph is already hash-consed, so *structurally identical* nodes
//! share an id for free. What hash-consing cannot see is that the labels
//! of an [`EinSpec`] are local names: `A *_(ij,j,i) x` and
//! `A *_(uv,v,u) x` denote the same contraction, and by Lemma 2 so does
//! `x *_(j,ij,i) A`. The derivative constructions mint fresh labels all
//! the time, so semantically equal products routinely land on distinct
//! nodes. This pass rebuilds the sub-DAG of *all* roots jointly
//! bottom-up, putting every multiplication into a canonical form
//! (first-appearance relabeling + a deterministic operand order chosen
//! across the swapped variant) so the graph's interner merges them —
//! loss, gradient and Hessian roots end up sharing one sub-DAG.
//!
//! The pass is numerically exact up to operand order: relabeling never
//! changes the evaluation, and swapping operands is elementwise-commutes
//! (Lemma 2).

use crate::einsum::{EinSpec, Label};
use crate::ir::{Graph, NodeId, Op};
use std::collections::{HashMap, HashSet};

/// Relabel `spec` so its distinct labels become `0, 1, 2, …` in order of
/// first appearance over `s1 ++ s2 ++ s3`. Injective, therefore
/// semantics-preserving; two specs with the same label *pattern* map to
/// the same canonical spec.
pub(crate) fn canon_relabel(spec: &EinSpec) -> EinSpec {
    let mut seen: Vec<Label> = Vec::new();
    for &l in spec.s1.iter().chain(&spec.s2).chain(&spec.s3) {
        if !seen.contains(&l) {
            seen.push(l);
        }
    }
    spec.relabel(|l| seen.iter().position(|&s| s == l).unwrap() as Label)
}

/// Build the canonical `Mul` node for `a *_spec b`: the cheaper-ordered
/// of `(a, b, canon(spec))` and the Lemma-2 swap `(b, a, canon(swapped))`
/// under a deterministic total order, so both operand orders dedupe to
/// one node.
pub(crate) fn canonical_mul(g: &mut Graph, a: NodeId, b: NodeId, spec: &EinSpec) -> NodeId {
    let fwd = canon_relabel(spec);
    let swp = canon_relabel(&spec.swapped());
    let fwd_key = (a, b, &fwd.s1, &fwd.s2, &fwd.s3);
    let swp_key = (b, a, &swp.s1, &swp.s2, &swp.s3);
    if swp_key < fwd_key {
        g.mul(b, a, swp)
    } else {
        g.mul(a, b, fwd)
    }
}

/// Rebuild the sub-DAG of `roots` in canonical form. Returns the new
/// roots (same order, duplicates preserved) and the number of distinct
/// reachable nodes that merged away.
pub fn cse(g: &mut Graph, roots: &[NodeId]) -> (Vec<NodeId>, usize) {
    let order = g.topo(roots);
    let before = order.len();
    let mut map: HashMap<NodeId, NodeId> = HashMap::with_capacity(before);
    for id in order {
        let new = match g.op(id).clone() {
            Op::Var(_) | Op::Const(_) | Op::Delta { .. } => id,
            Op::Add(a, b) => {
                let (a, b) = (map[&a], map[&b]);
                g.add(a, b) // Graph::add already orders operands canonically
            }
            Op::Mul(a, b, spec) => {
                let (a, b) = (map[&a], map[&b]);
                canonical_mul(g, a, b, &spec)
            }
            Op::Elem(f, a) => {
                let a = map[&a];
                g.elem(f, a)
            }
            Op::GenUnary(f, a) => {
                let a = map[&a];
                g.gen_unary(f, a)
            }
        };
        map.insert(id, new);
    }
    let distinct: HashSet<NodeId> = map.values().copied().collect();
    let new_roots = roots.iter().map(|r| map[r]).collect();
    (new_roots, before - distinct.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{Env, Plan};
    use crate::tensor::Tensor;

    #[test]
    fn canon_relabel_is_pattern_only() {
        let a = EinSpec::parse("ij,jk->ik");
        let b = EinSpec::new(vec![40, 7], vec![7, 12], vec![40, 12]);
        assert_eq!(canon_relabel(&a), canon_relabel(&b));
        assert_eq!(canon_relabel(&a).s1, vec![0, 1]);
        assert_eq!(canon_relabel(&a).s2, vec![1, 2]);
        assert_eq!(canon_relabel(&a).s3, vec![0, 2]);
    }

    #[test]
    fn relabel_equivalent_muls_merge() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let x = g.var("x", &[4]);
        let m1 = g.mul(a, x, EinSpec::parse("ij,j->i"));
        let m2 = g.mul(a, x, EinSpec::new(vec![7, 9], vec![9], vec![7]));
        assert_ne!(m1, m2, "hash-consing alone must not see through labels");
        let s = g.add(m1, m2);
        let (roots, merged) = cse(&mut g, &[s]);
        assert!(merged >= 1, "relabel-equivalent Muls should merge");
        // exactly one Mul survives below the new root
        let muls = g
            .topo(&roots)
            .iter()
            .filter(|&&n| matches!(g.op(n), Op::Mul(..)))
            .count();
        assert_eq!(muls, 1);
        // and the rebuilt root is 2·(A x): m1 + m1 canonicalises through
        // the x + x = … path only under simplify; here it must stay Add
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 1));
        env.insert("x", Tensor::randn(&[4], 2));
        let want = Plan::new(&g, &[s]).run(&g, &env);
        let got = Plan::new(&g, &roots).run(&g, &env);
        assert!(got[0].allclose(&want[0], 1e-13, 1e-14));
    }

    #[test]
    fn swapped_operand_muls_merge() {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let x = g.var("x", &[4]);
        let m1 = g.mul(a, x, EinSpec::parse("ij,j->i"));
        let m2 = g.mul(x, a, EinSpec::parse("j,ij->i"));
        assert_ne!(m1, m2);
        let s = g.add(m1, m2);
        let (roots, merged) = cse(&mut g, &[s]);
        assert!(merged >= 1, "Lemma-2 swapped Muls should merge");
        let muls = g
            .topo(&roots)
            .iter()
            .filter(|&&n| matches!(g.op(n), Op::Mul(..)))
            .count();
        assert_eq!(muls, 1);
    }

    #[test]
    fn joint_roots_share_one_subdag() {
        // the same contraction written with different labels under two
        // different roots collapses to one node across the root set
        let mut g = Graph::new();
        let a = g.var("A", &[5, 5]);
        let x = g.var("x", &[5]);
        let m1 = g.mul(a, x, EinSpec::parse("ij,j->i"));
        let m2 = g.mul(a, x, EinSpec::new(vec![3, 8], vec![8], vec![3]));
        let r1 = g.elem(crate::ir::Elem::Exp, m1);
        let r2 = g.elem(crate::ir::Elem::Tanh, m2);
        let before = g.topo(&[r1, r2]).len();
        let (roots, merged) = cse(&mut g, &[r1, r2]);
        assert_eq!(merged, 1);
        assert_eq!(g.topo(&roots).len(), before - 1);
    }

    #[test]
    fn canonical_graph_is_fixpoint() {
        let mut g = Graph::new();
        let a = g.var("A", &[4, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let e = g.elem(crate::ir::Elem::Exp, ax);
        let f = g.sum_all(e);
        let (r1, _) = cse(&mut g, &[f]);
        let (r2, merged) = cse(&mut g, &r1);
        assert_eq!(r1, r2, "CSE must be idempotent");
        assert_eq!(merged, 0);
    }

    #[test]
    fn diagonal_specs_survive() {
        // repeated operand labels (diagonal extraction) must pass through
        let mut g = Graph::new();
        let a = g.var("A", &[3, 3]);
        let one = g.scalar(1.0);
        let d = g.mul(a, one, EinSpec::parse("ii,->i"));
        let (roots, _) = cse(&mut g, &[d]);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 3], 3));
        let want = Plan::new(&g, &[d]).run(&g, &env);
        let got = Plan::new(&g, &roots).run(&g, &env);
        assert_eq!(got[0], want[0]);
    }
}
