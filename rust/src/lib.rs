//! # tensorcalc — A Simple and Efficient Tensor Calculus for Machine Learning
//!
//! Reproduction of Laue, Mitterreiter, Giesen (2020): an efficient tensor
//! calculus — forward/reverse/cross-country mode automatic differentiation
//! and higher-order-derivative compression — for tensor expressions in
//! Einstein notation (the generic multiplication `C = A *_(s1,s2,s3) B`).
//!
//! The crate is organised as the three-layer stack described in
//! ARCHITECTURE.md at the repository root:
//!
//! * [`ir`], [`autodiff`], [`simplify`], [`opt`] — the paper's
//!   contribution: the expression DAG in Einstein notation and the
//!   differentiation modes (Theorems 5–10), cross-country reordering
//!   (§3.3) and derivative compression (§3.3), plus the graph optimizer
//!   (global CSE with einsum-spec canonicalization + cost-driven
//!   contraction reassociation) that sits between autodiff and plan
//!   compilation.
//! * [`tensor`], [`einsum`], [`eval`], [`exec`], [`solve`] — the dense
//!   evaluation substrate (the NumPy role in the paper's experiments).
//!   Two executors coexist by design: the [`eval`] *interpreter* is the
//!   reference oracle, while the [`exec`] *compiled* engine is the hot
//!   path — write-into einsums ([`einsum::einsum_into`]) bottoming out
//!   in a tiled/packed GEMM kernel with in-tile epilogue fusion, a
//!   static memory planner that compiles buffer lifetimes to fixed
//!   arena offsets (with the PR 1 buffer pool kept as the
//!   [`exec::ExecMemory::Pooled`] ablation), a plan cache keyed by
//!   graph fingerprint, and parallel execution of independent DAG
//!   levels on a persistent worker pool ([`util::worker_pool`]).
//!   `tests/exec_equivalence.rs`, `tests/tile_epilogue.rs` and
//!   `tests/memory_plan.rs` pin the two against each other and against
//!   brute force.
//! * [`problems`], [`baselines`] — the paper's three benchmark workloads
//!   and the per-entry framework baseline (§4).
//! * [`runtime`], [`coordinator`] — the PJRT bridge that loads the
//!   AOT-compiled JAX/Pallas artifacts (behind the `pjrt` cargo
//!   feature) and the derivative-evaluation service built on top; engine
//!   entries serve requests through cached [`exec::CompiledPlan`]s.
//! * [`obs`] — the zero-dependency tracing/profiling layer: both exec
//!   backends record per-instruction spans under an opt-in
//!   [`obs::TraceMode`], exported as a profile table or Chrome trace-event
//!   JSON; the serving side renders Prometheus-style metrics
//!   ([`coordinator::metrics`]).
//!
//! ## Quickstart
//!
//! ```no_run
//! use tensorcalc::prelude::*;
//!
//! // f(w) = sum(log(exp(-y .* (X w)) + 1))   — logistic regression
//! let mut g = Graph::new();
//! let x = g.var("X", &[4, 3]);
//! let w = g.var("w", &[3]);
//! let xw = g.matvec(x, w);
//! let nxw = g.neg(xw);
//! let e = g.elem(Elem::Exp, nxw);
//! let one = g.constant(1.0, &[4]);
//! let s = g.add(e, one);
//! let l = g.elem(Elem::Log, s);
//! let loss = g.sum_all(l);
//! let grad = reverse_gradient(&mut g, loss, w);
//! let mut env = Env::new();
//! env.insert("X", Tensor::randn(&[4, 3], 1));
//! env.insert("w", Tensor::randn(&[3], 2));
//! let gval = eval(&g, grad, &env);
//! assert_eq!(gval.shape(), &[3]);
//! ```

pub mod autodiff;
pub mod baselines;
pub mod coordinator;
pub mod einsum;
pub mod error;
pub mod eval;
pub mod exec;
pub mod figures;
pub mod ir;
pub mod obs;
pub mod opt;
pub mod parser;
pub mod problems;
pub mod runtime;
pub mod simplify;
pub mod solve;
pub mod tensor;
pub mod util;

/// Convenience re-exports of the public API surface.
pub mod prelude {
    pub use crate::autodiff::compress::{compress_derivative, CompressedDerivative};
    pub use crate::autodiff::cross_country::optimize_contractions;
    pub use crate::autodiff::forward::forward_derivative;
    pub use crate::autodiff::hessian::{hessian, hessian_compressed, hessian_vector_product, jacobian};
    pub use crate::autodiff::reverse::{reverse_derivative, reverse_gradient};
    pub use crate::einsum::{einsum, einsum_into, EinScratch, EinSpec, EinsumPlan};
    pub use crate::eval::{eval, eval_many, eval_many_opts, eval_many_with, Env, Plan};
    pub use crate::exec::{
        batch_graph, global_plan_cache, BackendKind, CompiledPlan, EpilogueMode, ExecMemory,
        PlanCache, PlanOutput,
    };
    pub use crate::ir::{Elem, Graph, NodeId, Op};
    pub use crate::obs::{chrome_trace_json, Profile, Trace, TraceMode};
    pub use crate::opt::{compact, optimize, report, OptLevel, OptStats};
    pub use crate::simplify::simplify;
    pub use crate::tensor::Tensor;
}
