//! `tensorcalc` — CLI for the tensor-calculus reproduction.
//!
//! Subcommands (args hand-parsed; the offline build has no clap):
//!
//! ```text
//! tensorcalc demo                           quick tour on Expression (1)
//! tensorcalc derive <problem> [--n N] [--mode reverse|cc|compressed]
//!                   [--backend cpu|direct] [--dot]
//!                   [--trace off|profile|json=PATH]
//!                             profile = per-instruction table,
//!                             json    = Chrome trace-event file
//!
//! Every subcommand accepts `--simd off|avx2|avx512|neon` to force the
//! kernel dispatch tier (same values as the `TC_SIMD` env var; the
//! blocking geometry takes `TC_GEMM_BLOCKING="MR,NR,MC,KC,NC"`).
//! tensorcalc bench fig2|fig3|newton [--sizes a,b,c] [--secs S] [--full]
//! tensorcalc artifacts [--dir D]            list + smoke-run AOT artifacts
//! tensorcalc serve [--requests N] [--batch B] [--backend cpu|direct]
//!                  [--deadline-ms MS] [--shed reject|oldest|block[:MS]]
//!                  [--prom PATH]            coordinator demo with metrics
//!                                           (B = max dynamic batch, 1 = off;
//!                                           --deadline-ms gives every request
//!                                           a deadline budget, --shed picks
//!                                           the full-queue policy;
//!                                           --prom dumps Prometheus text)
//! ```

use tensorcalc::coordinator::{Coordinator, EngineEntry, Request, ShedPolicy};
use tensorcalc::error::{Context as _, Result};
use tensorcalc::figures;
use tensorcalc::{anyhow, bail};
use tensorcalc::ir::{Elem, Graph};
use tensorcalc::prelude::*;
use tensorcalc::problems::{logistic_regression, matrix_factorization, neural_net};
use tensorcalc::simplify::{dag_size, flop_estimate};
use tensorcalc::tensor::Tensor;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

struct Args {
    flags: Vec<(String, String)>,
    positional: Vec<String>,
}

impl Args {
    fn parse(raw: &[String]) -> Self {
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                    it.next().unwrap().clone()
                } else {
                    "true".into()
                };
                flags.push((name.to_string(), val));
            } else {
                positional.push(a.clone());
            }
        }
        Args { flags, positional }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    fn sizes(&self, default: &[usize]) -> Vec<usize> {
        self.get("sizes")
            .map(|s| s.split(',').map(|x| x.parse().expect("bad size")).collect())
            .unwrap_or_else(|| default.to_vec())
    }

    fn secs(&self, default: f64) -> f64 {
        self.get("secs").map(|s| s.parse().expect("bad secs")).unwrap_or(default)
    }

    fn backend(&self) -> Result<BackendKind> {
        match self.get("backend") {
            None => Ok(BackendKind::default()),
            Some(s) => {
                BackendKind::parse(s).ok_or_else(|| anyhow!("unknown backend {} (cpu|direct)", s))
            }
        }
    }

    /// Apply `--simd TIER` (force the kernel dispatch tier) before any
    /// plan compiles; errors on unknown names or unsupported CPUs.
    fn apply_simd(&self) -> Result<()> {
        if let Some(s) = self.get("simd") {
            let isa = tensorcalc::util::simd::Isa::parse(s)
                .ok_or_else(|| anyhow!("unknown --simd {} (off|avx2|avx512|neon)", s))?;
            if !isa.supported() {
                bail!("--simd {}: this CPU does not support {}", s, isa.name());
            }
            tensorcalc::util::simd::set_isa(isa);
        }
        Ok(())
    }
}

fn run() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let cmd = raw.first().cloned().unwrap_or_else(|| "help".into());
    let args = Args::parse(&raw[raw.len().min(1)..]);
    args.apply_simd()?;
    match cmd.as_str() {
        "demo" => demo(),
        "derive" => derive(&args),
        "bench" => bench(&args),
        "artifacts" => artifacts(&args),
        "serve" => serve(&args),
        _ => {
            println!(
                "tensorcalc — A Simple and Efficient Tensor Calculus for ML (reproduction)\n\n\
                 usage:\n  tensorcalc demo\n  tensorcalc derive <logreg|matfac|mlp> \
                 [--n N] [--mode reverse|cc|compressed] [--backend cpu|direct] [--dot] \
                 [--trace off|profile|json=PATH]\n  \
                 tensorcalc bench <fig2|fig3|newton> [--sizes a,b,c] [--secs S] [--full]\n  \
                 tensorcalc artifacts [--dir D]\n  tensorcalc serve [--requests N] \
                 [--batch B] [--backend cpu|direct] [--deadline-ms MS] \
                 [--shed reject|oldest|block[:MS]] [--prom PATH]\n\n\
                 all subcommands: [--simd off|avx2|avx512|neon] forces kernel dispatch\n\
                 env: TC_SIMD=off|avx2|avx512|neon, TC_GEMM_BLOCKING=MR,NR,MC,KC,NC"
            );
            Ok(())
        }
    }
}

/// Quick tour: Expression (1) from the paper, derivative + simplification.
fn demo() -> Result<()> {
    let (m, n) = (4usize, 3usize);
    let mut g = Graph::new();
    let x = g.var("X", &[m, n]);
    let w = g.var("w", &[n]);
    let xw = g.matvec(x, w);
    let e = g.elem(Elem::Exp, xw);
    let one = g.constant(1.0, &[m]);
    let s = g.add(e, one);
    let inv = g.elem(Elem::Recip, s);
    let prod = g.hadamard(inv, e);
    let y = g.tmatvec(x, prod); // Expression (1): Xᵀ((exp(Xw)+1)⁻¹ ⊙ exp(Xw))
    println!("Expression (1) of the paper:\n  {}\n", g.render(y));
    println!("DAG ({} nodes):\n{}", dag_size(&g, y), g.program(&[y]));

    let jac = reverse_derivative(&mut g, y, &[w])[0];
    let jac = simplify(&mut g, &[jac])[0];
    println!(
        "∂/∂w (reverse mode, simplified, {} nodes, ~{} flops @ this size):\n{}",
        dag_size(&g, jac),
        flop_estimate(&g, jac),
        g.program(&[jac])
    );

    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[m, n], 1));
    env.insert("w", Tensor::randn(&[n], 2));
    let j = eval(&g, jac, &env);
    println!("evaluated Jacobian {:?}", j);
    Ok(())
}

fn derive(args: &Args) -> Result<()> {
    let problem = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("logreg");
    let n: usize = args.get("n").map(|v| v.parse().unwrap()).unwrap_or(8);
    let mode = args.get("mode").unwrap_or("reverse");
    let mut w = match problem {
        "logreg" => logistic_regression(2 * n, n),
        "matfac" => matrix_factorization(n, n, 5, false),
        "mlp" => neural_net(n, 10, 2 * n),
        other => bail!("unknown problem {}", other),
    };
    println!("problem={} n={} loss DAG: {} nodes", problem, n, dag_size(&w.g, w.loss));
    {
        let isa = tensorcalc::util::simd::active_isa();
        let blk = tensorcalc::util::simd::blocking();
        println!(
            "kernels: simd={} blocking=MR{},NR{},MC{},KC{},NC{}",
            isa.name(),
            blk.mr,
            blk.nr,
            blk.mc,
            blk.kc,
            blk.nc
        );
    }
    let node = match mode {
        "reverse" => w.hessian(),
        "cc" => w.hessian_cross_country(),
        "compressed" => {
            let comp = w.hessian_compressed();
            println!(
                "compressed: {} (ratio {:.3e})",
                comp.is_compressed(),
                comp.compression_ratio(&w.g)
            );
            comp.eval_node()
        }
        other => bail!("unknown mode {}", other),
    };
    println!(
        "Hessian[{}] : shape {:?}, {} nodes, ~{} flops",
        mode,
        w.g.shape(node),
        dag_size(&w.g, node),
        flop_estimate(&w.g, node)
    );
    // what the graph optimizer (the eval_many / plan-cache pipeline) does
    // to this DAG before compilation, and what the executor's static
    // memory planner packs the result into — one optimize run for both
    {
        let backend = args.backend()?;
        let mut g2 = w.g.clone();
        let o = tensorcalc::opt::optimize(&mut g2, &[node], tensorcalc::opt::OptLevel::Full);
        println!("optimizer (CSE + reassociation): {}", o.stats);
        let plan = CompiledPlan::with_backend(&g2, &o.roots, backend);
        println!(
            "memory plan ({} instrs, {} levels, backend {}): {}",
            plan.len(),
            plan.depth(),
            plan.backend().name(),
            plan.pool_stats()
        );
        run_trace(args, &g2, &o.roots, &w.env, backend)?;
    }
    if args.get("dot").is_some() {
        println!("{}", w.g.to_dot(&[node]));
    } else {
        println!("{}", w.g.program(&[node]));
    }
    Ok(())
}

/// `derive --trace`: re-compile the optimized graph with tracing on,
/// run it once on the workload's sample inputs, and either print the
/// profile table (`--trace profile`) or write a Perfetto-loadable
/// Chrome trace-event file (`--trace json=PATH`).
fn run_trace(
    args: &Args,
    g: &Graph,
    roots: &[NodeId],
    env: &Env,
    backend: BackendKind,
) -> Result<()> {
    let spec = match args.get("trace") {
        None | Some("off") => return Ok(()),
        Some(s) => s,
    };
    let (mode, json_path) = if spec == "profile" {
        (TraceMode::Profile, None)
    } else if let Some(p) = spec.strip_prefix("json=") {
        // Trace mode adds level/epilogue spans — the timeline export
        // wants them, the aggregate table doesn't need them
        (TraceMode::Trace, Some(p.to_string()))
    } else {
        bail!("unknown --trace {} (off|profile|json=PATH)", spec);
    };
    let plan = CompiledPlan::with_options(
        g,
        roots,
        true,
        EpilogueMode::default(),
        ExecMemory::default(),
        backend,
        mode,
    );
    let (_outputs, trace) = plan.run_traced(env);
    let info = plan.plan_info();
    match json_path {
        Some(path) => {
            std::fs::write(&path, chrome_trace_json(&trace, &info))
                .with_context(|| format!("writing {}", path))?;
            println!("wrote Chrome trace ({} spans) to {}", trace.spans.len(), path);
        }
        None => println!("{}", Profile::build(&trace, &info).render_table(10)),
    }
    Ok(())
}

fn bench(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("fig3");
    match which {
        "fig2" => {
            let rows = figures::fig2(
                &["logreg", "matfac", "mlp"],
                &args.sizes(&[16, 32, 64, 128]),
                args.secs(0.2),
            );
            figures::print_table("Figure 2 — function value + gradient (CPU)", &rows);
        }
        "fig3" => {
            let full = args.get("full").is_some();
            let rows = figures::fig3(
                &["logreg", "matfac", "mlp"],
                &args.sizes(if full { &[16, 32, 64] } else { &[8, 16, 32] }),
                args.secs(0.2),
                true,
            );
            figures::print_table("Figure 3 — Hessian (CPU)", &rows);
            println!("\nspeedup ours(reverse) vs framework(per-entry):");
            for (p, n, s) in figures::speedup(&rows, "framework", "ours(reverse)") {
                println!("  {:<8} n={:<5} {:>8.1}×", p, n, s);
            }
        }
        "newton" => {
            let rows = figures::newton(&args.sizes(&[20, 50, 100]), 10, args.secs(0.2));
            figures::print_table("§3.3 — compressed vs full Newton system (matfac, k=10)", &rows);
        }
        other => bail!("unknown bench {}", other),
    }
    Ok(())
}

fn artifacts(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .or_else(tensorcalc::runtime::artifacts_dir)
        .ok_or_else(|| anyhow!("no artifacts found — run `make artifacts`"))?;
    let mut rt = tensorcalc::runtime::Runtime::open(&dir)?;
    println!("artifacts in {:?}:", dir);
    for name in rt.names() {
        let art = rt.artifact(&name)?;
        println!(
            "  {:<20} inputs={:?} outputs={:?}",
            art.name, art.input_shapes, art.output_names
        );
        let inputs: Vec<Tensor> = art
            .input_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| Tensor::randn(s, 42 + i as u64).scale(0.1))
            .collect();
        let t0 = std::time::Instant::now();
        let out = art.run(&inputs)?;
        println!(
            "      ✓ ran in {} → {:?}",
            tensorcalc::util::fmt_secs(t0.elapsed().as_secs_f64()).trim(),
            out.iter().map(|t| t.shape().to_vec()).collect::<Vec<_>>()
        );
    }
    Ok(())
}

/// Coordinator demo: register the logreg gradient (engine) and the AOT
/// artifacts (PJRT), fire a synthetic request load, report metrics.
fn serve(args: &Args) -> Result<()> {
    let requests: usize = args.get("requests").map(|v| v.parse().unwrap()).unwrap_or(200);
    let batch: usize = args
        .get("batch")
        .map(|v| v.parse().unwrap())
        .unwrap_or(tensorcalc::coordinator::DEFAULT_MAX_BATCH);
    let backend = args.backend()?;
    let deadline_ms: Option<u64> =
        args.get("deadline-ms").map(|v| v.parse().expect("bad --deadline-ms"));
    let shed = match args.get("shed") {
        None => ShedPolicy::default(),
        Some(s) => ShedPolicy::parse(s)
            .ok_or_else(|| anyhow!("unknown --shed {} (reject|oldest|block[:MS])", s))?,
    };
    let (m, n) = (256usize, 128usize);
    let mut c = Coordinator::new(1024);

    // engine-backed gradient entry (compiled plan via the global cache),
    // prewarmed so no batch bucket compiles on the serving path
    {
        let mut w = logistic_regression(m, n);
        let grad = w.gradient();
        let roots = [w.loss, grad];
        c.register_engine(
            "logreg_grad_engine",
            EngineEntry::compiled_with(
                &w.g,
                &roots,
                vec![
                    ("X".into(), vec![m, n]),
                    ("y".into(), vec![m]),
                    ("w".into(), vec![n]),
                ],
                OptLevel::default(),
                ExecMemory::default(),
                backend,
            )
            .with_max_batch(batch)
            .with_prewarm(true)
            .with_shed_policy(shed),
        );
    }
    // PJRT-backed entries
    if let Some(dir) = tensorcalc::runtime::artifacts_dir() {
        c.register_runtime(dir, &["logreg_val_grad".into(), "logreg_hess".into()])?;
    } else {
        println!("(no artifacts — PJRT entries skipped)");
    }

    println!(
        "entries: {:?} (engine max batch {}, backend {})",
        c.entries(),
        batch,
        backend.name()
    );
    let x = Tensor::randn(&[m, n], 1);
    let y = Tensor::randn(&[m], 2).map(f64::signum);
    let wv = Tensor::randn(&[n], 3).scale(0.1);

    let has_pjrt = c.entries().iter().any(|e| e == "logreg_val_grad");
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..requests {
        let entry = match i % 3 {
            0 => "logreg_grad_engine",
            1 if has_pjrt => "logreg_val_grad",
            _ if has_pjrt => "logreg_hess",
            _ => "logreg_grad_engine",
        };
        let inputs = if entry == "logreg_grad_engine" {
            vec![x.clone(), y.clone(), wv.clone()]
        } else {
            vec![wv.clone(), x.clone(), y.clone()]
        };
        let req = match deadline_ms {
            Some(ms) => Request::new(inputs).with_deadline(std::time::Duration::from_millis(ms)),
            None => Request::new(inputs),
        };
        match c.submit_with(entry, req) {
            Ok(rx) => pending.push(rx),
            Err(e) if e.is_retryable() => {
                // backpressure: drain one then continue
                if let Some(rx) = pending.pop() {
                    let _ = rx.recv();
                }
            }
            // non-retryable admission refusals (e.g. an already-expired
            // deadline) are counted in the metrics and reported below
            Err(_) => {}
        }
    }
    let mut ok = 0usize;
    for rx in pending {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = c.metrics().snapshot();
    println!(
        "\ncompleted {}/{} in {:.3}s → {:.0} req/s",
        ok,
        snap.submitted,
        wall,
        ok as f64 / wall
    );
    println!(
        "outcomes: {} ok, {} errors, {} shed, {} expired | \
         rejected at admission: {} queue-full, {} expired | policy {}{}",
        snap.completed,
        snap.errors,
        snap.shed,
        snap.expired,
        snap.rejected_full,
        snap.rejected_expired,
        shed,
        deadline_ms.map(|ms| format!(", deadline {}ms", ms)).unwrap_or_default()
    );
    println!("{:<22} {:>8} {:>12} {:>12}", "entry", "count", "p50", "p99");
    for (name, count, p50, p99) in snap.per_entry {
        println!(
            "{:<22} {:>8} {:>12} {:>12}",
            name,
            count,
            tensorcalc::util::fmt_secs(p50),
            tensorcalc::util::fmt_secs(p99)
        );
    }
    // the `stats` request surface: what the optimizer did per entry and
    // where its batched-plan compiles happened (registration vs serving)
    for es in c.stats() {
        let opt = match es.opt_stats {
            Some(s) => s.to_string(),
            None => "frozen at OptLevel::None".into(),
        };
        println!(
            "stats {}: max_batch {}, prewarmed buckets {:?}, compiles \
             {} prewarm / {} lazy | {}",
            es.name, es.max_batch, es.prewarmed_buckets, es.prewarm_compiles, es.lazy_compiles, opt
        );
    }
    if let Some(path) = args.get("prom") {
        std::fs::write(path, c.metrics().render_prometheus())
            .with_context(|| format!("writing {}", path))?;
        println!("wrote Prometheus metrics to {}", path);
    }
    Ok(())
}
