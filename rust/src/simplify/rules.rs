//! The rewrite rules, applied through "smart constructors" while the DAG
//! is rebuilt bottom-up.

use crate::einsum::{EinSpec, Label};
use crate::ir::{Elem, Graph, NodeId, Op};
use std::collections::HashMap;

pub(crate) struct Simplifier<'g> {
    pub g: &'g mut Graph,
    pub memo: HashMap<NodeId, NodeId>,
    /// set whenever a rewrite rule fires anywhere in the pass — the
    /// fixpoint loop in [`super::simplify`] stops as soon as a whole
    /// pass completes without firing (interior-node convergence, not
    /// just root equality)
    pub changed: bool,
}

impl<'g> Simplifier<'g> {
    pub fn simp(&mut self, id: NodeId) -> NodeId {
        if let Some(&m) = self.memo.get(&id) {
            return m;
        }
        let res = match self.g.op(id).clone() {
            Op::Var(_) | Op::Const(_) | Op::Delta { .. } => id,
            Op::Add(a, b) => {
                let a = self.simp(a);
                let b = self.simp(b);
                self.make_add(a, b)
            }
            Op::Mul(a, b, spec) => {
                let a = self.simp(a);
                let b = self.simp(b);
                self.make_mul(a, b, spec)
            }
            Op::Elem(f, a) => {
                let a = self.simp(a);
                self.make_elem(f, a)
            }
            Op::GenUnary(f, a) => {
                let a = self.simp(a);
                self.g.gen_unary(f, a)
            }
        };
        self.memo.insert(id, res);
        res
    }

    fn make_add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        // 0 + x = x
        if self.g.is_const_value(a, 0.0) {
            self.changed = true;
            return b;
        }
        if self.g.is_const_value(b, 0.0) {
            self.changed = true;
            return a;
        }
        // constant folding
        if let (Some(va), Some(vb)) = (self.g.const_value(a), self.g.const_value(b)) {
            let shape = self.g.shape(a).to_vec();
            self.changed = true;
            return self.g.constant(va + vb, &shape);
        }
        // x + x = 2x
        if a == b {
            let l: Vec<Label> = (0..self.g.order(a) as Label).collect();
            let two = self.g.scalar(2.0);
            self.changed = true;
            return self.make_mul(a, two, EinSpec::new(l.clone(), vec![], l));
        }
        self.g.add(a, b)
    }

    fn make_elem(&mut self, f: Elem, a: NodeId) -> NodeId {
        if let Some(v) = self.g.const_value(a) {
            let shape = self.g.shape(a).to_vec();
            self.changed = true;
            return self.g.constant(f.apply(v), &shape);
        }
        // involution cancellation: −(−x), 1/(1/x)
        if let Op::Elem(inner, x) = self.g.op(a) {
            if (f == Elem::Neg && *inner == Elem::Neg)
                || (f == Elem::Recip && *inner == Elem::Recip)
            {
                self.changed = true;
                return *x;
            }
        }
        self.g.elem(f, a)
    }

    pub(crate) fn make_mul(&mut self, a: NodeId, b: NodeId, spec: EinSpec) -> NodeId {
        let dim_of = |g: &Graph, l: Label| -> usize {
            spec.s1
                .iter()
                .position(|&x| x == l)
                .map(|p| g.shape(a)[p])
                .or_else(|| spec.s2.iter().position(|&x| x == l).map(|p| g.shape(b)[p]))
                .unwrap()
        };

        // zero annihilates
        if self.g.is_const_value(a, 0.0) || self.g.is_const_value(b, 0.0) {
            let shape = spec.output_shape(self.g.shape(a), self.g.shape(b)).unwrap();
            self.changed = true;
            return self.g.constant(0.0, &shape);
        }
        // both constant → fold, including the implicit summation factor
        if let (Some(va), Some(vb)) = (self.g.const_value(a), self.g.const_value(b)) {
            let factor: f64 = spec
                .summed_labels()
                .iter()
                .map(|&l| dim_of(self.g, l) as f64)
                .product();
            let shape = spec.output_shape(self.g.shape(a), self.g.shape(b)).unwrap();
            self.changed = true;
            return self.g.constant(va * vb * factor, &shape);
        }
        // normalize: delta on the right; otherwise constants on the right
        let a_delta = matches!(self.g.op(a), Op::Delta { .. });
        let b_delta = matches!(self.g.op(b), Op::Delta { .. });
        if a_delta && !b_delta {
            self.changed = true;
            return self.make_mul(b, a, spec.swapped());
        }
        if !a_delta && !b_delta && self.g.const_value(a).is_some() && self.g.const_value(b).is_none()
        {
            self.changed = true;
            return self.make_mul(b, a, spec.swapped());
        }

        // constant operand: fold its axes away when possible
        if let Some(c) = self.g.const_value(b) {
            if !spec.s2.is_empty() {
                // every s2 label must be provided by A or be summed away
                let ok = spec
                    .s2
                    .iter()
                    .all(|l| spec.s1.contains(l) || !spec.s3.contains(l));
                if ok {
                    // private summed s2 labels contribute a dimension factor
                    let mut seen: Vec<Label> = Vec::new();
                    let mut factor = 1.0;
                    for &l in &spec.s2 {
                        if !spec.s1.contains(&l) && !spec.s3.contains(&l) && !seen.contains(&l)
                        {
                            factor *= dim_of(self.g, l) as f64;
                            seen.push(l);
                        }
                    }
                    let k = self.g.scalar(c * factor);
                    self.changed = true;
                    return self.make_mul(
                        a,
                        k,
                        EinSpec::new(spec.s1.clone(), vec![], spec.s3.clone()),
                    );
                }
            } else {
                // scalar constant
                if c == 1.0 && spec.s3 == spec.s1 {
                    self.changed = true;
                    return a; // identity
                }
                // pure permute of a Mul: push the permutation into the
                // inner product's output labels
                if c == 1.0
                    && spec.is_sum_free()
                    && spec.s3.len() == spec.s1.len()
                {
                    if let Op::Mul(p, q, inner) = self.g.op(a).clone() {
                        // outer s1 position i ↔ inner output axis i
                        let new_s3: Vec<Label> = spec
                            .s3
                            .iter()
                            .map(|l| {
                                let pos = spec.s1.iter().position(|x| x == l).unwrap();
                                inner.s3[pos]
                            })
                            .collect();
                        self.changed = true;
                        return self.make_mul(
                            p,
                            q,
                            EinSpec::new(inner.s1.clone(), inner.s2.clone(), new_s3),
                        );
                    }
                }
                // compose nested scalar-const muls (scales, permutes and
                // reductions): (x *_(sa1,∅,sa3) c1) *_(sb1,∅,sb3) c2
                //            =  x *_(sa1,∅,compose) (c1·c2)
                if let Op::Mul(x, k1, inner) = self.g.op(a).clone() {
                    if let Some(c1) = self.g.const_value(k1) {
                        let distinct = spec
                            .s1
                            .iter()
                            .enumerate()
                            .all(|(i, l)| !spec.s1[i + 1..].contains(l));
                        if inner.s2.is_empty() && distinct {
                            // outer sb1 position i corresponds to inner
                            // output axis i; translate sb3 through it
                            let composed_s3: Vec<Label> = spec
                                .s3
                                .iter()
                                .map(|l| {
                                    let p =
                                        spec.s1.iter().position(|x| x == l).unwrap();
                                    inner.s3[p]
                                })
                                .collect();
                            let k = self.g.scalar(c1 * c);
                            self.changed = true;
                            return self.make_mul(
                                x,
                                k,
                                EinSpec::new(inner.s1.clone(), vec![], composed_s3),
                            );
                        }
                    }
                }
            }
        }

        // delta elimination (the paper's unit-tensor removal)
        if let Op::Delta { dims } = self.g.op(b).clone() {
            if let Some(n) = self.delta_step(a, &dims, &spec) {
                self.changed = true;
                return n;
            }
        }

        self.g.mul(a, b, spec)
    }

    /// One delta-elimination step on `A *_(s1,s2,s3) δ`. Returns the
    /// rewritten node if any pair of the delta can be contracted.
    ///
    /// For a pair `(u, v)` of delta labels (`δ[… u …, … v …]`):
    /// * `Σ_u A[… u …] δ[u,v] = A[… v …]` when `u` is summed, appears in
    ///   `s1` and nowhere else — the index is *renamed* (and symmetrically
    ///   for `v`),
    /// * a pair whose two labels coincide is a constant-1 factor,
    /// * a fully private summed pair contributes a factor `dim`.
    ///
    /// Pairs whose labels all reach the output are *not* eliminated —
    /// those are exactly the compressible unit tensors of §3.3.
    fn delta_step(&mut self, a: NodeId, dims: &[usize], spec: &EinSpec) -> Option<NodeId> {
        let k = dims.len();
        debug_assert_eq!(spec.s2.len(), 2 * k);
        let occ_s1 = |l: Label| spec.s1.iter().filter(|&&x| x == l).count();
        let occ_s2 = |l: Label| spec.s2.iter().filter(|&&x| x == l).count();
        let in_s3 = |l: Label| spec.s3.contains(&l);

        for m in 0..k {
            let (u, v) = (spec.s2[m], spec.s2[m + k]);

            // helper: rebuild with pair m removed and s1 relabeled
            let rebuild = |s: &mut Simplifier,
                           new_s1: Vec<Label>,
                           factor: f64|
             -> NodeId {
                let mut new_dims = dims.to_vec();
                new_dims.remove(m);
                let mut new_s2: Vec<Label> = spec.s2.clone();
                new_s2.remove(m + k); // remove back slot first (higher index)
                new_s2.remove(m);
                let new_b = if new_dims.is_empty() {
                    s.g.scalar(1.0)
                } else {
                    s.g.delta(&new_dims)
                };
                let inner =
                    s.make_mul(a, new_b, EinSpec::new(new_s1, new_s2, spec.s3.clone()));
                if factor == 1.0 {
                    inner
                } else {
                    let l: Vec<Label> = (0..s.g.order(inner) as Label).collect();
                    let f = s.g.scalar(factor);
                    s.make_mul(inner, f, EinSpec::new(l.clone(), vec![], l))
                }
            };

            if u == v {
                // δ[…u…, …u…] pair is identically 1; if u is otherwise
                // unused and summed it contributes a factor dim(u)
                let private =
                    occ_s1(u) == 0 && occ_s2(u) == 2 && !in_s3(u);
                let factor = if private { dims[m] as f64 } else { 1.0 };
                return Some(rebuild(self, spec.s1.clone(), factor));
            }
            // Σ_u: contract into A, renaming u → v
            if !in_s3(u) && occ_s2(u) == 1 && occ_s1(u) >= 1 {
                let new_s1: Vec<Label> =
                    spec.s1.iter().map(|&l| if l == u { v } else { l }).collect();
                return Some(rebuild(self, new_s1, 1.0));
            }
            // Σ_v: contract into A, renaming v → u
            if !in_s3(v) && occ_s2(v) == 1 && occ_s1(v) >= 1 {
                let new_s1: Vec<Label> =
                    spec.s1.iter().map(|&l| if l == v { u } else { l }).collect();
                return Some(rebuild(self, new_s1, 1.0));
            }
            // fully private pair: Σ_{u,v} δ[u,v] = dim
            if occ_s1(u) == 0
                && occ_s1(v) == 0
                && !in_s3(u)
                && !in_s3(v)
                && occ_s2(u) == 1
                && occ_s2(v) == 1
            {
                return Some(rebuild(self, spec.s1.clone(), dims[m] as f64));
            }
            // one label summed & private, the other reaches the output
            // from the delta itself: Σ_u δ[u,v] = 1 for each v — the pair
            // collapses to a broadcast only if A can still provide v; it
            // cannot, so this case must keep the delta. (compression
            // handles it at the root.)
        }
        None
    }
}
