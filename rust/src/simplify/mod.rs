//! Algebraic simplification of expression DAGs.
//!
//! The paper (§4): *"our implementation performs some expression
//! simplification like constant folding and removal of zero and identity
//! tensors."* These rewrites are what turn the raw Theorem-8 pullback
//! chains into the familiar compact derivative expressions — in
//! particular the **delta-contraction rule** `Σ_u A[…u…]·δ[u,v] = A[…v…]`
//! that eliminates the unit-tensor seeds, and its failure case (a delta
//! whose indices all reach the output) is exactly what the compression
//! scheme of §3.3 exploits.

mod rules;

use crate::ir::{Graph, NodeId};
use rules::Simplifier;
use std::collections::HashMap;

/// Simplify the sub-DAGs rooted at `roots`; returns the new roots.
/// Runs rewrite passes to a fixpoint (bounded): a pass in which no
/// rewrite rule fired anywhere in the DAG ends the loop immediately —
/// the `Simplifier` tracks rule firings itself, so convergence is
/// detected at interior nodes too, not only through root-`Vec` equality.
pub fn simplify(g: &mut Graph, roots: &[NodeId]) -> Vec<NodeId> {
    let mut current = roots.to_vec();
    for _ in 0..8 {
        let mut s = Simplifier { g, memo: HashMap::new(), changed: false };
        let next: Vec<NodeId> = current.iter().map(|&r| s.simp(r)).collect();
        if !s.changed || next == current {
            return next;
        }
        current = next;
    }
    current
}

/// Simplify a single root.
pub fn simplify_one(g: &mut Graph, root: NodeId) -> NodeId {
    simplify(g, &[root])[0]
}

/// Count the nodes in the sub-DAG (a cheap complexity metric used by
/// tests and by the benchmark reports).
pub fn dag_size(g: &Graph, root: NodeId) -> usize {
    g.topo(&[root]).len()
}

/// Estimated flop count of evaluating the sub-DAG once: for every Mul the
/// size of its iteration space (product of all distinct label dims), for
/// element-wise ops the element count. Thin single-root wrapper around
/// the optimizer's cost model ([`crate::opt::cost`]), kept for API
/// stability; use `opt::cost::dag_flops` directly for joint root sets.
pub fn flop_estimate(g: &Graph, root: NodeId) -> u128 {
    crate::opt::cost::dag_flops(g, &[root])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::reverse::reverse_gradient;
    use crate::einsum::EinSpec;
    use crate::eval::{eval, Env};
    use crate::ir::{Elem, Op};
    use crate::tensor::Tensor;

    fn eval_both(g: &mut Graph, root: NodeId, env: &Env) -> (Tensor, Tensor, NodeId) {
        let before = eval(g, root, env);
        let s = simplify_one(g, root);
        let after = eval(g, s, env);
        (before, after, s)
    }

    #[test]
    fn add_zero_is_removed() {
        let mut g = Graph::new();
        let x = g.var("x", &[3]);
        let z = g.constant(0.0, &[3]);
        let y = g.add(x, z);
        let s = simplify_one(&mut g, y);
        assert_eq!(s, x);
    }

    #[test]
    fn mul_by_zero_collapses() {
        let mut g = Graph::new();
        let x = g.var("x", &[3, 4]);
        let z = g.constant(0.0, &[4]);
        let y = g.mul(x, z, EinSpec::parse("ij,j->i"));
        let s = simplify_one(&mut g, y);
        assert!(g.is_const_value(s, 0.0));
        assert_eq!(g.shape(s), &[3]);
    }

    #[test]
    fn identity_permute_is_removed() {
        let mut g = Graph::new();
        let x = g.var("x", &[3, 4]);
        let one = g.scalar(1.0);
        let y = g.mul(x, one, EinSpec::parse("ij,->ij"));
        let s = simplify_one(&mut g, y);
        assert_eq!(s, x);
    }

    #[test]
    fn double_transpose_cancels() {
        let mut g = Graph::new();
        let x = g.var("x", &[3, 4]);
        let t1 = g.transpose(x, &[1, 0]);
        let t2 = g.transpose(t1, &[1, 0]);
        let s = simplify_one(&mut g, t2);
        assert_eq!(s, x);
    }

    #[test]
    fn constants_fold_through_mul() {
        let mut g = Graph::new();
        let a = g.constant(2.0, &[3]);
        let b = g.constant(5.0, &[3]);
        // Σ_i a[i]·b[i] = 3·10 = 30
        let y = g.mul(a, b, EinSpec::parse("i,i->"));
        let s = simplify_one(&mut g, y);
        assert_eq!(g.const_value(s), Some(30.0));
    }

    #[test]
    fn constants_fold_through_elem_and_add() {
        let mut g = Graph::new();
        let a = g.constant(0.0, &[2]);
        let e = g.elem(Elem::Exp, a); // exp(0) = 1
        let b = g.constant(2.0, &[2]);
        let y = g.add(e, b);
        let s = simplify_one(&mut g, y);
        assert_eq!(g.const_value(s), Some(3.0));
    }

    #[test]
    fn delta_contraction_renames() {
        // Σ_j A[i,j] δ[j,k] = A[i,k]
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let d = g.delta(&[4]);
        let y = g.mul(a, d, EinSpec::parse("ij,jk->ik"));
        let s = simplify_one(&mut g, y);
        assert_eq!(s, a, "δ contraction should eliminate the Mul:\n{}", g.program(&[s]));
    }

    #[test]
    fn delta_contraction_with_permuted_output() {
        // Σ_j A[i,j] δ[j,k] -> output ki: must become a transpose of A
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let d = g.delta(&[4]);
        let y = g.mul(a, d, EinSpec::parse("ij,jk->ki"));
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 1));
        let (before, after, s) = eval_both(&mut g, y, &env);
        assert!(before.allclose(&after, 1e-12, 1e-12));
        // no delta node should survive
        assert!(
            !g.topo(&[s]).iter().any(|&n| matches!(g.op(n), Op::Delta { .. })),
            "{}",
            g.program(&[s])
        );
    }

    #[test]
    fn delta_trace_becomes_constant_dimension() {
        // Σ_{u,v} δ[u,v] δ[u,v] = n  (both labels summed)
        let mut g = Graph::new();
        let d = g.delta(&[5]);
        let y = g.mul(d, d, EinSpec::parse("uv,uv->"));
        let s = simplify_one(&mut g, y);
        assert_eq!(g.const_value(s), Some(5.0), "{}", g.program(&[s]));
    }

    #[test]
    fn order4_delta_contracts_pairwise() {
        // Σ_{k,l} A[k,l] δ[k,l,m,n] = A[m,n]
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let d = g.delta(&[3, 4]);
        let y = g.mul(a, d, EinSpec::parse("kl,klmn->mn"));
        let s = simplify_one(&mut g, y);
        assert_eq!(s, a, "{}", g.program(&[s]));
    }

    #[test]
    fn gradient_of_xtax_simplifies_to_small_dag() {
        // the raw reverse-mode gradient carries δ seeds; after
        // simplification no delta may remain and the result must agree
        let mut g = Graph::new();
        let a = g.var("A", &[4, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let f = g.dot(x, ax);
        let grad = reverse_gradient(&mut g, f, x);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[4, 4], 1));
        env.insert("x", Tensor::randn(&[4], 2));
        let (before, after, s) = eval_both(&mut g, grad, &env);
        assert!(before.allclose(&after, 1e-10, 1e-12));
        assert!(
            !g.topo(&[s]).iter().any(|&n| matches!(g.op(n), Op::Delta { .. })),
            "gradient should be delta-free:\n{}",
            g.program(&[s])
        );
        assert!(dag_size(&g, s) <= 10, "DAG too big:\n{}", g.program(&[s]));
    }

    #[test]
    fn simplify_preserves_semantics_randomized() {
        // random-ish DAG: f = Σ relu(Aᵀ(exp(Ax) ⊙ x + x))
        let mut g = Graph::new();
        let a = g.var("A", &[4, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let e = g.elem(Elem::Exp, ax);
        let h = g.hadamard(e, x);
        let hx = g.add(h, x);
        let at = g.tmatvec(a, hx);
        let r = g.elem(Elem::Relu, at);
        let f = g.sum_all(r);
        let grad = reverse_gradient(&mut g, f, x);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[4, 4], 3));
        env.insert("x", Tensor::randn(&[4], 4));
        let (before, after, _) = eval_both(&mut g, grad, &env);
        assert!(
            before.allclose(&after, 1e-9, 1e-11),
            "diff {}",
            before.max_abs_diff(&after)
        );
    }

    #[test]
    fn simplify_converges_and_is_idempotent() {
        // an already-canonical DAG must come back unchanged (the
        // no-rewrite-fired early exit), and re-simplifying a simplified
        // DAG must be the identity
        let mut g = Graph::new();
        let a = g.var("A", &[4, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let f = g.sum_all(ax);
        let s1 = simplify(&mut g, &[f]);
        let s2 = simplify(&mut g, &s1);
        assert_eq!(s1, s2);

        let grad = reverse_gradient(&mut g, f, x);
        let t1 = simplify(&mut g, &[grad]);
        let t2 = simplify(&mut g, &t1);
        assert_eq!(t1, t2);
    }

    #[test]
    fn flop_estimate_monotone_under_simplify() {
        let mut g = Graph::new();
        let a = g.var("A", &[8, 8]);
        let d = g.delta(&[8]);
        let y = g.mul(a, d, EinSpec::parse("ij,jk->ik"));
        let before = flop_estimate(&g, y);
        let s = simplify_one(&mut g, y);
        let after = flop_estimate(&g, s);
        assert!(after <= before);
    }
}
