//! Expression-language front end (matrixcalculus.org style): parse a
//! string like `"X'*(inv(exp(X*w)+1) .* exp(X*w))"` against declared
//! variable shapes into the expression DAG, ready for differentiation.

mod grammar;
pub use grammar::{parse_expr, ParseError, VarDecl};
