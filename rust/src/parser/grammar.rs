//! The expression language: a matrixcalculus.org-style front end.
//!
//! ```text
//! expr   := term (('+' | '-') term)*
//! term   := factor (('*' | '.*' | './' | '/') factor)*
//! factor := atom | '-' factor
//! atom   := number | ident | ident '(' expr ')' | '(' expr ')' | atom "'"
//! ```
//!
//! `*` is shape-driven (matrix·matrix, matrix·vector, scalar scaling,
//! row-vector·vector = inner product, vector·row-vector = outer product);
//! `.*` and `./` are element-wise. `'` is transpose. Supported functions:
//! `exp log relu sigmoid tanh sqrt abs sum norm2 tr diag inv` (element-wise
//! `inv` = the paper's `·⁻¹`).

use crate::einsum::EinSpec;
use crate::ir::{Elem, Graph, NodeId};
use std::fmt;

/// A variable declaration for the expression language.
#[derive(Clone, Debug)]
pub struct VarDecl {
    pub name: String,
    pub shape: Vec<usize>,
}

impl VarDecl {
    pub fn new(name: &str, shape: &[usize]) -> Self {
        VarDecl { name: name.into(), shape: shape.to_vec() }
    }
}

#[derive(Debug)]
pub struct ParseError(pub String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError(msg.into()))
}

// ------------------------------------------------------------- lexer

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Num(f64),
    Ident(String),
    Plus,
    Minus,
    Star,
    Slash,
    DotStar,
    DotSlash,
    LParen,
    RParen,
    Tick,
}

fn lex(src: &str) -> Result<Vec<Tok>, ParseError> {
    let mut out = Vec::new();
    let chars: Vec<char> = src.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        match c {
            ' ' | '\t' | '\n' | '\r' => i += 1,
            '+' => {
                out.push(Tok::Plus);
                i += 1;
            }
            '-' => {
                out.push(Tok::Minus);
                i += 1;
            }
            '*' => {
                out.push(Tok::Star);
                i += 1;
            }
            '/' => {
                out.push(Tok::Slash);
                i += 1;
            }
            '(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            '\'' => {
                out.push(Tok::Tick);
                i += 1;
            }
            '.' => {
                match chars.get(i + 1) {
                    Some('*') => {
                        out.push(Tok::DotStar);
                        i += 2;
                    }
                    Some('/') => {
                        out.push(Tok::DotSlash);
                        i += 2;
                    }
                    Some(d) if d.is_ascii_digit() => {
                        // .5 style number
                        let (n, len) = lex_number(&chars[i..])?;
                        out.push(Tok::Num(n));
                        i += len;
                    }
                    _ => return err(format!("unexpected '.' at {}", i)),
                }
            }
            d if d.is_ascii_digit() => {
                let (n, len) = lex_number(&chars[i..])?;
                out.push(Tok::Num(n));
                i += len;
            }
            a if a.is_alphabetic() || a == '_' => {
                let start = i;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                out.push(Tok::Ident(chars[start..i].iter().collect()));
            }
            other => return err(format!("unexpected character '{}'", other)),
        }
    }
    Ok(out)
}

fn lex_number(chars: &[char]) -> Result<(f64, usize), ParseError> {
    let mut len = 0;
    while len < chars.len()
        && (chars[len].is_ascii_digit()
            || chars[len] == '.'
            || (len > 0
                && (chars[len] == 'e' || chars[len] == 'E')
                && len + 1 < chars.len())
            || (len > 0
                && (chars[len] == '+' || chars[len] == '-')
                && (chars[len - 1] == 'e' || chars[len - 1] == 'E')))
    {
        len += 1;
    }
    let s: String = chars[..len].iter().collect();
    match s.parse() {
        Ok(n) => Ok((n, len)),
        Err(_) => err(format!("bad number '{}'", s)),
    }
}

// ------------------------------------------------------------- parser

/// A parsed value: the node plus a row-vector marker (`x'` on a vector).
#[derive(Clone, Copy)]
struct Val {
    node: NodeId,
    row: bool,
}

struct Parser<'g> {
    g: &'g mut Graph,
    toks: Vec<Tok>,
    pos: usize,
}

/// Parse `src` into the graph. Every identifier must be declared in
/// `decls` (shape inference is driven by the declarations).
pub fn parse_expr(g: &mut Graph, decls: &[VarDecl], src: &str) -> Result<NodeId, ParseError> {
    // declare variables up front so node ids are stable
    for d in decls {
        g.var(&d.name, &d.shape);
    }
    let toks = lex(src)?;
    let mut p = Parser { g, toks, pos: 0 };
    let v = p.expr()?;
    if p.pos != p.toks.len() {
        return err(format!("trailing tokens at {}", p.pos));
    }
    Ok(v.node)
}

impl<'g> Parser<'g> {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expr(&mut self) -> Result<Val, ParseError> {
        let mut lhs = self.term()?;
        while let Some(op) = self.peek().cloned() {
            match op {
                Tok::Plus | Tok::Minus => {
                    self.pos += 1;
                    let rhs = self.term()?;
                    let rhs_node = if op == Tok::Minus {
                        self.g.neg(rhs.node)
                    } else {
                        rhs.node
                    };
                    let (a, b) = self.broadcast_pair(lhs.node, rhs_node)?;
                    lhs = Val { node: self.g.add(a, b), row: lhs.row && rhs.row };
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    fn term(&mut self) -> Result<Val, ParseError> {
        let mut lhs = self.factor()?;
        while let Some(op) = self.peek().cloned() {
            match op {
                Tok::Star => {
                    self.pos += 1;
                    let rhs = self.factor()?;
                    lhs = self.mul(lhs, rhs)?;
                }
                Tok::DotStar => {
                    self.pos += 1;
                    let rhs = self.factor()?;
                    let (a, b) = self.broadcast_pair(lhs.node, rhs.node)?;
                    lhs = Val { node: self.g.hadamard(a, b), row: lhs.row };
                }
                Tok::DotSlash => {
                    self.pos += 1;
                    let rhs = self.factor()?;
                    let inv = self.g.elem(Elem::Recip, rhs.node);
                    let (a, b) = self.broadcast_pair(lhs.node, inv)?;
                    lhs = Val { node: self.g.hadamard(a, b), row: lhs.row };
                }
                Tok::Slash => {
                    self.pos += 1;
                    let rhs = self.factor()?;
                    if !self.g.shape(rhs.node).is_empty() {
                        return err("'/' needs a scalar divisor (use ./ element-wise)");
                    }
                    let inv = self.g.elem(Elem::Recip, rhs.node);
                    lhs = self.mul(lhs, Val { node: inv, row: false })?;
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    fn factor(&mut self) -> Result<Val, ParseError> {
        if self.peek() == Some(&Tok::Minus) {
            self.pos += 1;
            let v = self.factor()?;
            return Ok(Val { node: self.g.neg(v.node), row: v.row });
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<Val, ParseError> {
        let t = match self.next() {
            Some(t) => t,
            None => return err("unexpected end of input"),
        };
        let mut v = match t {
            Tok::Num(n) => Val { node: self.g.scalar(n), row: false },
            Tok::LParen => {
                let v = self.expr()?;
                if self.next() != Some(Tok::RParen) {
                    return err("expected ')'");
                }
                v
            }
            Tok::Ident(name) => {
                if self.peek() == Some(&Tok::LParen) {
                    self.pos += 1;
                    let arg = self.expr()?;
                    if self.next() != Some(Tok::RParen) {
                        return err(format!("expected ')' after {}(…", name));
                    }
                    self.call(&name, arg)?
                } else {
                    match self.g.var_id(&name) {
                        Some(id) => Val { node: id, row: false },
                        None => return err(format!("undeclared variable '{}'", name)),
                    }
                }
            }
            other => return err(format!("unexpected token {:?}", other)),
        };
        while self.peek() == Some(&Tok::Tick) {
            self.pos += 1;
            v = self.transpose(v)?;
        }
        Ok(v)
    }

    fn transpose(&mut self, v: Val) -> Result<Val, ParseError> {
        match self.g.order(v.node) {
            0 => Ok(v),
            1 => Ok(Val { node: v.node, row: !v.row }),
            2 => Ok(Val { node: self.g.transpose(v.node, &[1, 0]), row: false }),
            r => err(format!("cannot transpose an order-{} tensor", r)),
        }
    }

    /// Shape-driven `*`.
    fn mul(&mut self, a: Val, b: Val) -> Result<Val, ParseError> {
        let (ra, rb) = (self.g.order(a.node), self.g.order(b.node));
        let v = match (ra, rb) {
            // scalar scaling
            (0, _) => {
                let l: Vec<u32> = (0..rb as u32).collect();
                Val {
                    node: self.g.mul(b.node, a.node, EinSpec::new(l.clone(), vec![], l)),
                    row: b.row,
                }
            }
            (_, 0) => {
                let l: Vec<u32> = (0..ra as u32).collect();
                Val {
                    node: self.g.mul(a.node, b.node, EinSpec::new(l.clone(), vec![], l)),
                    row: a.row,
                }
            }
            (2, 2) => Val { node: self.g.matmul(a.node, b.node), row: false },
            (2, 1) => {
                if b.row {
                    return err("matrix * row-vector is not defined (transpose it?)");
                }
                Val { node: self.g.matvec(a.node, b.node), row: false }
            }
            (1, 2) => {
                if !a.row {
                    return err("column-vector * matrix is not defined (use x'·A)");
                }
                // x' A = Aᵀ x
                Val { node: self.g.tmatvec(b.node, a.node), row: true }
            }
            (1, 1) => match (a.row, b.row) {
                (true, false) => Val { node: self.g.dot(a.node, b.node), row: false },
                (false, true) => Val { node: self.g.outer(a.node, b.node), row: false },
                _ => return err("vector * vector needs x'*y (inner) or x*y' (outer), or use .*"),
            },
            (ra, rb) => return err(format!("'*' undefined for orders {} and {}", ra, rb)),
        };
        Ok(v)
    }

    fn call(&mut self, name: &str, arg: Val) -> Result<Val, ParseError> {
        let node = arg.node;
        let v = match name {
            "exp" => self.g.elem(Elem::Exp, node),
            "log" => self.g.elem(Elem::Log, node),
            "relu" => self.g.elem(Elem::Relu, node),
            "sigmoid" => self.g.elem(Elem::Sigmoid, node),
            "tanh" => self.g.elem(Elem::Tanh, node),
            "sqrt" => self.g.elem(Elem::Sqrt, node),
            "abs" => self.g.elem(Elem::Abs, node),
            "inv" => self.g.elem(Elem::Recip, node), // the paper's element-wise ·⁻¹
            "sum" => self.g.sum_all(node),
            "norm2" => self.g.norm2(node),
            "tr" => {
                if self.g.order(node) != 2 {
                    return err("tr(·) needs a matrix");
                }
                let d = self.g.diag_of(node);
                self.g.sum_all(d)
            }
            "diag" => match self.g.order(node) {
                1 => {
                    // diag(v)[i,j] = v[i]·δ[i,j]
                    let n = self.g.shape(node)[0];
                    let d = self.g.delta(&[n]);
                    self.g.mul(node, d, EinSpec::parse("i,ij->ij"))
                }
                2 => self.g.diag_of(node),
                _ => return err("diag(·) needs a vector or a matrix"),
            },
            other => return err(format!("unknown function '{}'", other)),
        };
        Ok(Val { node: v, row: false })
    }

    /// Allow `tensor + scalar` by broadcasting the scalar constant.
    fn broadcast_pair(&mut self, a: NodeId, b: NodeId) -> Result<(NodeId, NodeId), ParseError> {
        let sa = self.g.shape(a).to_vec();
        let sb = self.g.shape(b).to_vec();
        if sa == sb {
            return Ok((a, b));
        }
        if sb.is_empty() {
            if let Some(c) = self.g.const_value(b) {
                return Ok((a, self.g.constant(c, &sa)));
            }
            // computed scalar: broadcast with an explicit ones-mul
            let l: Vec<u32> = (0..sa.len() as u32).collect();
            let ones = self.g.constant(1.0, &sa);
            let bb = self.g.mul(ones, b, EinSpec::new(l.clone(), vec![], l));
            return Ok((a, bb));
        }
        if sa.is_empty() {
            let (b2, a2) = self.broadcast_pair(b, a)?;
            return Ok((a2, b2));
        }
        err(format!("shape mismatch {:?} vs {:?}", sa, sb))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::reverse::reverse_gradient;
    use crate::eval::{eval, fd_gradient, Env};
    use crate::simplify::simplify_one;
    use crate::tensor::Tensor;

    fn decls() -> Vec<VarDecl> {
        vec![
            VarDecl::new("A", &[3, 4]),
            VarDecl::new("B", &[4, 3]),
            VarDecl::new("x", &[4]),
            VarDecl::new("y", &[3]),
            VarDecl::new("w", &[4]),
        ]
    }

    fn env() -> Env {
        let mut e = Env::new();
        e.insert("A", Tensor::randn(&[3, 4], 1));
        e.insert("B", Tensor::randn(&[4, 3], 2));
        e.insert("x", Tensor::randn(&[4], 3));
        e.insert("y", Tensor::randn(&[3], 4));
        e.insert("w", Tensor::randn(&[4], 5).scale(0.3));
        e
    }

    #[test]
    fn parses_matvec_and_shapes() {
        let mut g = Graph::new();
        let id = parse_expr(&mut g, &decls(), "A*x").unwrap();
        assert_eq!(g.shape(id), &[3]);
    }

    #[test]
    fn quadratic_form_parses_and_evaluates() {
        let mut g = Graph::new();
        let id = parse_expr(&mut g, &decls(), "x'*(B*(A*x))").unwrap();
        assert_eq!(g.shape(id), &[] as &[usize]);
        let e = env();
        let got = eval(&g, id, &e).item();
        // manual: xᵀ B A x
        let a = e.get("A").unwrap();
        let b = e.get("B").unwrap();
        let x = e.get("x").unwrap();
        let ax = crate::einsum::einsum(&EinSpec::parse("ij,j->i"), a, x);
        let bax = crate::einsum::einsum(&EinSpec::parse("ij,j->i"), b, &ax);
        let want = x.flat_dot(&bax);
        assert!((got - want).abs() < 1e-10);
    }

    #[test]
    fn paper_expression_1_parses() {
        // Xᵀ((exp(X w)+1)⁻¹ ⊙ exp(X w)) with A in the X role
        let mut g = Graph::new();
        let src = "A'*(inv(exp(A*w)+1) .* exp(A*w))";
        let id = parse_expr(&mut g, &decls(), src).unwrap();
        assert_eq!(g.shape(id), &[4]);
        // and it is differentiable end-to-end
        let e = env();
        let before = eval(&g, id, &e);
        assert!(before.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn parsed_gradient_matches_fd() {
        let mut g = Graph::new();
        let src = "sum(log(exp(A*w)+1))";
        let f = parse_expr(&mut g, &decls(), src).unwrap();
        let w = g.var_id("w").unwrap();
        let grad = reverse_gradient(&mut g, f, w);
        let grad = simplify_one(&mut g, grad);
        let e = env();
        let gv = eval(&g, grad, &e);
        let want = fd_gradient(&g, f, "w", &e, 1e-6);
        assert!(gv.allclose(&want, 1e-5, 1e-7), "diff {}", gv.max_abs_diff(&want));
    }

    #[test]
    fn outer_and_inner_products() {
        let mut g = Graph::new();
        let outer = parse_expr(&mut g, &decls(), "x*y'").unwrap();
        assert_eq!(g.shape(outer), &[4, 3]);
        let inner = parse_expr(&mut g, &decls(), "x'*x").unwrap();
        assert_eq!(g.shape(inner), &[] as &[usize]);
    }

    #[test]
    fn diag_and_trace() {
        let mut g = Graph::new();
        let d = parse_expr(&mut g, &[VarDecl::new("v", &[3])], "diag(v)").unwrap();
        assert_eq!(g.shape(d), &[3, 3]);
        let mut e = Env::new();
        e.insert("v", Tensor::new(&[3], vec![1., 2., 3.]));
        let dv = eval(&g, d, &e);
        assert_eq!(dv.at(&[1, 1]), 2.0);
        assert_eq!(dv.at(&[0, 1]), 0.0);

        let mut g2 = Graph::new();
        let t = parse_expr(&mut g2, &[VarDecl::new("M", &[3, 3])], "tr(M)").unwrap();
        let mut e2 = Env::new();
        e2.insert("M", Tensor::eye(3).scale(2.0));
        assert_eq!(eval(&g2, t, &e2).item(), 6.0);
    }

    #[test]
    fn scalar_arithmetic_and_precedence() {
        let mut g = Graph::new();
        let id = parse_expr(&mut g, &[], "2+3*4").unwrap();
        assert_eq!(eval(&g, id, &Env::new()).item(), 14.0);
        let id = parse_expr(&mut g, &[], "(2+3)*4").unwrap();
        assert_eq!(eval(&g, id, &Env::new()).item(), 20.0);
        let id = parse_expr(&mut g, &[], "-2*3").unwrap();
        assert_eq!(eval(&g, id, &Env::new()).item(), -6.0);
        let id = parse_expr(&mut g, &[], "8/2").unwrap();
        assert_eq!(eval(&g, id, &Env::new()).item(), 4.0);
    }

    #[test]
    fn error_cases() {
        let mut g = Graph::new();
        assert!(parse_expr(&mut g, &decls(), "z*x").is_err()); // undeclared
        assert!(parse_expr(&mut g, &decls(), "x*y").is_err()); // vec*vec
        assert!(parse_expr(&mut g, &decls(), "A*x+").is_err()); // dangling op
        assert!(parse_expr(&mut g, &decls(), "A*(x").is_err()); // unbalanced
        assert!(parse_expr(&mut g, &decls(), "foo(x)").is_err()); // unknown fn
        assert!(parse_expr(&mut g, &decls(), "A+x").is_err()); // shape mismatch
    }

    #[test]
    fn scalar_broadcast_in_addition() {
        let mut g = Graph::new();
        let id = parse_expr(&mut g, &decls(), "exp(x)+1").unwrap();
        assert_eq!(g.shape(id), &[4]);
        let e = env();
        let v = eval(&g, id, &e);
        let x = e.get("x").unwrap();
        for i in 0..4 {
            assert!((v.data()[i] - (x.data()[i].exp() + 1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn matrix_transpose_in_products() {
        let mut g = Graph::new();
        let id = parse_expr(&mut g, &decls(), "A'*y").unwrap();
        assert_eq!(g.shape(id), &[4]);
        let e = env();
        let got = eval(&g, id, &e);
        let want = crate::einsum::einsum(
            &EinSpec::parse("ji,j->i"),
            e.get("A").unwrap(),
            e.get("y").unwrap(),
        );
        assert!(got.allclose(&want, 1e-12, 1e-12));
    }
}
