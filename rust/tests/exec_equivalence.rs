//! Differential verification of the compiled executor (in-crate seeded
//! generators — the offline build has no proptest):
//!
//! * `CompiledPlan::run == Plan::run == einsum_naive` to 1e-12 over
//!   randomized `EinSpec`s and over curated spec families
//!   (matmul / diagonal / presum / permuted / scalar),
//! * `einsum_into` against the allocating `einsum` on the same specs,
//! * pool-reuse regressions: the same plan run repeatedly must neither
//!   alias stale buffers nor drift, and after warm-up the pool must stop
//!   allocating (beyond the root buffers that leave with the caller),
//! * finite-difference oracles for the compiled path: gradients and
//!   Hessians of all three `problems::*` workloads, where the FD side
//!   runs on the *interpreter* and the symbolic side on `CompiledPlan`.

use tensorcalc::autodiff::reverse::reverse_derivative;
use tensorcalc::einsum::{einsum, einsum_into, einsum_naive, EinScratch, EinSpec, Label};
use tensorcalc::eval::{fd_gradient, fd_jacobian, Env, Plan};
use tensorcalc::exec::{BackendKind, CompiledPlan, EpilogueMode, ExecMemory, PlanCache};
use tensorcalc::ir::{Elem, Graph, NodeId, Op};
use tensorcalc::obs::TraceMode;
use tensorcalc::problems::{logistic_regression, matrix_factorization, neural_net};
use tensorcalc::tensor::{Tensor, XorShift};

/// Generate a random valid spec + matching operand shapes (diagonals,
/// private labels, permuted outputs and scalar operands all reachable).
fn random_spec(rng: &mut XorShift) -> (EinSpec, Vec<usize>, Vec<usize>) {
    let n_labels = 1 + rng.below(4);
    let dims: Vec<usize> = (0..n_labels).map(|_| 1 + rng.below(4)).collect();
    let ra = 1 + rng.below(3);
    let rb = rng.below(3);
    let s1: Vec<Label> = (0..ra).map(|_| rng.below(n_labels) as Label).collect();
    let s2: Vec<Label> = (0..rb).map(|_| rng.below(n_labels) as Label).collect();
    let mut used: Vec<Label> = Vec::new();
    for &l in s1.iter().chain(&s2) {
        if !used.contains(&l) {
            used.push(l);
        }
    }
    let mut s3 = Vec::new();
    for &l in &used {
        if rng.below(2) == 0 {
            s3.push(l);
        }
    }
    for i in (1..s3.len()).rev() {
        let j = rng.below(i + 1);
        s3.swap(i, j);
    }
    let a_shape: Vec<usize> = s1.iter().map(|&l| dims[l as usize]).collect();
    let b_shape: Vec<usize> = s2.iter().map(|&l| dims[l as usize]).collect();
    (EinSpec::new(s1, s2, s3), a_shape, b_shape)
}

/// Check one spec across all four evaluators: naive oracle, interpreter
/// einsum, write-into einsum, and a single-Mul graph on both executors.
fn check_all_paths(case: u64, spec: &EinSpec, sa: &[usize], sb: &[usize]) {
    let a = Tensor::randn(sa, 9000 + case);
    let b = Tensor::randn(sb, 10000 + case);
    let naive = einsum_naive(spec, &a, &b);
    let interp = einsum(spec, &a, &b);
    assert!(
        interp.allclose(&naive, 1e-12, 1e-12),
        "case {}: einsum vs naive on {}: diff {}",
        case,
        spec,
        interp.max_abs_diff(&naive)
    );

    // write-into path, with a poisoned output buffer
    let mut out = Tensor::fill(naive.shape(), f64::NAN);
    let mut scratch = EinScratch::default();
    einsum_into(spec, &a, &b, &mut out, &mut scratch);
    assert!(
        out.allclose(&naive, 1e-12, 1e-12),
        "case {}: einsum_into vs naive on {}: diff {}",
        case,
        spec,
        out.max_abs_diff(&naive)
    );

    // graph with one Mul node through both executors
    let mut g = Graph::new();
    let av = g.var("A", sa);
    let bv = g.var("B", sb);
    let y = g.mul(av, bv, spec.clone());
    let mut env = Env::new();
    env.insert("A", a);
    env.insert("B", b);
    let compiled = CompiledPlan::new(&g, &[y]).run(&env);
    let interp_plan = Plan::new(&g, &[y]).run(&g, &env);
    assert!(
        compiled[0].allclose(&naive, 1e-12, 1e-12),
        "case {}: CompiledPlan vs naive on {}: diff {}",
        case,
        spec,
        compiled[0].max_abs_diff(&naive)
    );
    assert!(
        compiled[0].allclose(&interp_plan[0], 1e-12, 1e-12),
        "case {}: CompiledPlan vs Plan on {}: diff {}",
        case,
        spec,
        compiled[0].max_abs_diff(&interp_plan[0])
    );
}

#[test]
fn prop_compiled_einsum_matches_oracles_on_200_random_specs() {
    let mut rng = XorShift::new(4242);
    for case in 0..200 {
        let (spec, sa, sb) = random_spec(&mut rng);
        check_all_paths(case, &spec, &sa, &sb);
    }
}

#[test]
fn curated_spec_families_match() {
    let families: &[(&str, &[usize], &[usize])] = &[
        // matmul family
        ("ij,jk->ik", &[4, 5], &[5, 6]),
        ("ji,jk->ik", &[5, 4], &[5, 6]),
        ("ij,kj->ik", &[4, 5], &[6, 5]),
        ("ij,j->i", &[4, 5], &[5]),
        ("i,i->", &[7], &[7]),
        ("aij,ajk->aik", &[3, 2, 4], &[3, 4, 2]),
        // diagonal family
        ("ii,->i", &[4, 4], &[]),
        ("ii,->", &[4, 4], &[]),
        ("ij,ii->j", &[4, 4], &[4, 4]),
        ("iji,j->ij", &[3, 4, 3], &[4]),
        // presum family (private labels summed out)
        ("ij,k->i", &[3, 4], &[5]),
        ("ijk,l->ik", &[2, 3, 4], &[5]),
        // permuted outputs
        ("ij,jk->ki", &[3, 4], &[4, 5]),
        ("ijk,->kji", &[2, 3, 4], &[]),
        ("ij,kl->ljki", &[2, 3], &[4, 5]),
        // scalar operands
        (",->", &[], &[]),
        ("ij,->ij", &[3, 4], &[]),
        (",ij->ij", &[], &[3, 4]),
        ("ij,->", &[3, 4], &[]),
    ];
    for (case, (sig, sa, sb)) in families.iter().enumerate() {
        let spec = EinSpec::parse(sig);
        check_all_paths(500 + case as u64, &spec, sa, sb);
    }
}

/// Random scalar-expression DAGs (same generator family as
/// tests/property.rs): the whole compiled pipeline against the
/// interpreter, including shared subexpressions, adds, elementwise
/// chains and matrix products.
fn random_scalar_expr(rng: &mut XorShift, g: &mut Graph, depth: usize) -> NodeId {
    let x = g.var("x", &[4]);
    let a = g.var("A", &[4, 4]);
    let mut v = g.matvec(a, x);
    for _ in 0..depth {
        v = match rng.below(6) {
            0 => g.elem(Elem::Tanh, v),
            1 => g.elem(Elem::Sigmoid, v),
            2 => {
                let e = g.elem(Elem::Exp, v);
                let half = g.scale(e, 0.2);
                g.elem(Elem::Tanh, half)
            }
            3 => g.hadamard(v, x),
            4 => {
                let av = g.matvec(a, v);
                g.scale(av, 0.5)
            }
            _ => {
                let t = g.tmatvec(a, v);
                g.add(t, x)
            }
        };
    }
    let sq = g.elem(Elem::Square, v);
    g.sum_all(sq)
}

#[test]
fn prop_compiled_matches_interpreter_on_random_dags() {
    for seed in 0..30u64 {
        let mut rng = XorShift::new(seed);
        let mut g = Graph::new();
        let depth = 1 + (seed % 5) as usize;
        let f = random_scalar_expr(&mut rng, &mut g, depth);
        let x = g.var_id("x").unwrap();
        let grad = reverse_derivative(&mut g, f, &[x])[0];
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[4], seed + 1).scale(0.5));
        env.insert("A", Tensor::randn(&[4, 4], seed + 2).scale(0.5));
        let compiled = CompiledPlan::new(&g, &[f, grad]).run(&env);
        let interp = Plan::new(&g, &[f, grad]).run(&g, &env);
        for (c, i) in compiled.iter().zip(&interp) {
            assert!(
                c.allclose(i, 1e-12, 1e-13),
                "seed {}: compiled vs interpreter diff {}",
                seed,
                c.max_abs_diff(i)
            );
        }
    }
}

/// Element-wise-heavy DAGs with chains of depth ≥ 6 — the shapes the
/// fusion pass must collapse. Pins the fused `CompiledPlan` against the
/// unfused (PR 1) plan and the interpreter, with a multi-use tail so
/// leaves shared across groups stay materialised.
#[test]
fn prop_fused_deep_chains_match_interpreter_and_unfused() {
    for seed in 0..20u64 {
        let mut rng = XorShift::new(7000 + seed);
        let mut g = Graph::new();
        let x = g.var("x", &[5]);
        let a = g.var("A", &[5, 5]);
        let mut v = g.matvec(a, x);
        let steps = 6 + rng.below(6);
        for _ in 0..steps {
            v = match rng.below(5) {
                0 => g.elem(Elem::Tanh, v),
                1 => g.elem(Elem::Sigmoid, v),
                2 => g.scale(v, 0.7),
                3 => {
                    let c = g.constant(0.3, &[5]);
                    g.add(v, c)
                }
                _ => g.elem(Elem::Neg, v),
            };
        }
        let w = g.hadamard(v, v); // multi-use: v feeds two kernel slots
        let f = g.sum_all(w);
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[5], seed + 1).scale(0.5));
        env.insert("A", Tensor::randn(&[5, 5], seed + 2).scale(0.5));
        let fused = CompiledPlan::new(&g, &[f, v]);
        let unfused = CompiledPlan::with_fusion(&g, &[f, v], false);
        assert!(fused.len() < unfused.len(), "seed {}: chain did not fuse", seed);
        let got = fused.run(&env);
        let base = unfused.run(&env);
        let want = Plan::new(&g, &[f, v]).run(&g, &env);
        for ((gt, bt), wt) in got.iter().zip(&base).zip(&want) {
            assert!(
                gt.allclose(wt, 1e-12, 1e-13),
                "seed {}: fused vs interpreter diff {}",
                seed,
                gt.max_abs_diff(wt)
            );
            assert!(
                bt.allclose(wt, 1e-12, 1e-13),
                "seed {}: unfused vs interpreter diff {}",
                seed,
                bt.max_abs_diff(wt)
            );
        }
    }
}

/// A deep pure-`Elem` chain: the fused plan must collapse it into one
/// pipeline, cutting cold pool allocations versus one-buffer-per-node.
#[test]
fn fusion_cuts_fresh_pool_allocations_on_deep_elem_chain() {
    let mut g = Graph::new();
    let x = g.var("x", &[256]);
    let mut v = g.elem(Elem::Tanh, x);
    for _ in 0..9 {
        v = g.elem(Elem::Sigmoid, v);
        v = g.elem(Elem::Tanh, v);
    }
    let mut env = Env::new();
    env.insert("x", Tensor::randn(&[256], 7));
    // pooled mode: this test asserts the *pool's* bucket counters (the
    // planned default never touches them — tests/memory_plan.rs owns the
    // arena-side assertions)
    let fused = CompiledPlan::with_options(
        &g,
        &[v],
        true,
        EpilogueMode::default(),
        ExecMemory::Pooled,
        BackendKind::default(),
        TraceMode::Off,
    );
    let unfused = CompiledPlan::with_options(
        &g,
        &[v],
        false,
        EpilogueMode::default(),
        ExecMemory::Pooled,
        BackendKind::default(),
        TraceMode::Off,
    );
    let a = fused.run(&env);
    let b = unfused.run(&env);
    assert_eq!(a[0].data(), b[0].data(), "fusion changed the numerics");
    let fs = fused.pool_stats();
    let us = unfused.pool_stats();
    assert!(
        fs.fresh < us.fresh,
        "fusion must cut cold allocations: fused {:?} vs unfused {:?}",
        fs,
        us
    );
    assert_eq!(fs.fresh, 1, "a fully fused chain needs exactly the root buffer");
}

/// One wide level of many small independent nodes: forces the
/// work-stealing fork (level flops above the gate, every node below the
/// internal-parallelism cutoff) and pins it to the interpreter.
#[test]
fn work_stealing_level_matches_interpreter_on_wide_level() {
    let mut g = Graph::new();
    let x = g.var("x", &[4096]);
    let roots: Vec<NodeId> = (0..64).map(|i| g.scale(x, 1.0 + i as f64 * 0.01)).collect();
    let mut env = Env::new();
    env.insert("x", Tensor::randn(&[4096], 11));
    let plan = CompiledPlan::new(&g, &roots);
    let got = plan.run(&env);
    let want = Plan::new(&g, &roots).run(&g, &env);
    assert_eq!(got.len(), 64);
    for (i, (gt, wt)) in got.iter().zip(&want).enumerate() {
        assert!(
            gt.allclose(wt, 1e-12, 1e-14),
            "root {}: stolen-level result diverged, diff {}",
            i,
            gt.max_abs_diff(wt)
        );
    }
}

#[test]
fn pool_reuse_does_not_alias_or_drift() {
    // a DAG with many same-shaped intermediates so released buffers get
    // reacquired; repeated runs on *different* inputs must never see
    // stale data
    let mut g = Graph::new();
    let x = g.var("x", &[6]);
    let a = g.var("A", &[6, 6]);
    let mut v = g.matvec(a, x);
    for _ in 0..6 {
        let e = g.elem(Elem::Tanh, v);
        let w = g.matvec(a, e);
        v = g.add(w, x);
    }
    let f = g.norm2(v);
    let plan = CompiledPlan::new(&g, &[f, v]);
    let interp = Plan::new(&g, &[f, v]);

    for round in 0..10u64 {
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[6], 100 + round));
        env.insert("A", Tensor::randn(&[6, 6], 200 + round).scale(0.3));
        let got = plan.run(&env);
        let want = interp.run(&g, &env);
        assert!(
            got[0].allclose(&want[0], 1e-12, 1e-13) && got[1].allclose(&want[1], 1e-12, 1e-13),
            "round {}: pooled run diverged (stale buffer?): diff {} / {}",
            round,
            got[0].max_abs_diff(&want[0]),
            got[1].max_abs_diff(&want[1])
        );
    }
}

#[test]
fn pool_stops_allocating_after_warmup() {
    let mut w = logistic_regression(32, 8);
    let grad = w.gradient();
    // pooled ablation mode — the planned default bypasses the pool
    let plan = CompiledPlan::with_options(
        &w.g,
        &[w.loss, grad],
        true,
        EpilogueMode::default(),
        ExecMemory::Pooled,
        BackendKind::default(),
        TraceMode::Off,
    );
    let first = plan.run(&w.env);
    let cold = plan.pool_stats();
    let runs = 20u64;
    for _ in 0..runs {
        let again = plan.run(&w.env);
        assert_eq!(again[0].data(), first[0].data(), "repeated runs must be deterministic");
        assert_eq!(again[1].data(), first[1].data());
    }
    let warm = plan.pool_stats();
    // roots (two per run) leave with the caller; everything else must be
    // served from the pool
    assert!(
        warm.fresh <= cold.fresh + 2 * runs,
        "per-node allocations survived warm-up: {:?} -> {:?}",
        cold,
        warm
    );
    assert!(warm.reused > cold.reused, "pool never reused a buffer");
}

#[test]
fn same_plan_twice_from_cache_shares_pool_safely() {
    let cache = PlanCache::new();
    let mut w = logistic_regression(10, 4);
    let grad = w.gradient();
    let p1 = cache.get_or_compile(&w.g, &[grad]);
    let p2 = cache.get_or_compile(&w.g, &[grad]);
    let a = p1.run(&w.env);
    let b = p2.run(&w.env);
    assert_eq!(a[0].data(), b[0].data());
    assert_eq!(cache.len(), 1);
}

#[test]
fn compiled_handles_delta_and_const_roots() {
    // statics as direct roots and as operands
    let mut g = Graph::new();
    let d = g.delta(&[3]);
    let c = g.constant(4.0, &[3, 3]);
    let m = g.hadamard(d, c);
    let tr = g.sum_all(m); // trace · 4 = 12
    let plan = CompiledPlan::new(&g, &[tr, d, c]);
    let vals = plan.run(&Env::new());
    assert!((vals[0].item() - 12.0).abs() < 1e-12);
    assert_eq!(vals[1], Tensor::eye(3));
    assert_eq!(vals[2], Tensor::fill(&[3, 3], 4.0));
}

// ---------------------------------------------------------------------------
// Finite-difference oracles for the compiled path. The FD helpers run the
// interpreter internally; the symbolic values come from CompiledPlan.
// ---------------------------------------------------------------------------

fn wrt_name(g: &Graph, wrt: NodeId) -> String {
    match g.op(wrt) {
        Op::Var(n) => n.clone(),
        _ => unreachable!("wrt must be a variable"),
    }
}

#[test]
fn fd_gradients_of_all_workloads_on_compiled_path() {
    for mut w in [
        logistic_regression(6, 3),
        matrix_factorization(5, 5, 2, false),
        matrix_factorization(5, 4, 2, true),
        neural_net(4, 3, 5),
    ] {
        let grad = w.gradient();
        let name = w.name;
        let var = wrt_name(&w.g, w.wrt);
        let gv = CompiledPlan::new(&w.g, &[grad]).run(&w.env).pop().unwrap();
        let want = fd_gradient(&w.g, w.loss, &var, &w.env, 1e-6);
        assert!(
            gv.allclose(&want, 1e-4, 1e-6),
            "{}: compiled gradient vs FD, diff {}",
            name,
            gv.max_abs_diff(&want)
        );
    }
}

#[test]
fn fd_hessians_of_all_workloads_on_compiled_path() {
    for mut w in [
        logistic_regression(6, 3),
        matrix_factorization(5, 5, 2, false),
        neural_net(4, 2, 5),
    ] {
        let grad = w.gradient();
        let h = w.hessian();
        let name = w.name;
        let var = wrt_name(&w.g, w.wrt);
        let hv = CompiledPlan::new(&w.g, &[h]).run(&w.env).pop().unwrap();
        let want = fd_jacobian(&w.g, grad, &var, &w.env, 1e-5);
        assert!(
            hv.allclose(&want, 1e-3, 1e-5),
            "{}: compiled Hessian vs FD-of-gradient, diff {}",
            name,
            hv.max_abs_diff(&want)
        );
    }
}

#[test]
fn fd_compressed_hessians_on_compiled_path() {
    for mut w in [
        logistic_regression(8, 4),
        matrix_factorization(6, 6, 2, false),
        neural_net(4, 2, 5),
    ] {
        let grad = w.gradient();
        let comp = w.hessian_compressed();
        let name = w.name;
        let var = wrt_name(&w.g, w.wrt);
        let vals = CompiledPlan::new(&w.g, &[comp.eval_node()]).run(&w.env);
        let hv = comp.materialize(&vals[0]);
        let want = fd_jacobian(&w.g, grad, &var, &w.env, 1e-5);
        assert!(
            hv.allclose(&want, 1e-3, 1e-5),
            "{}: compiled compressed Hessian vs FD, diff {}",
            name,
            hv.max_abs_diff(&want)
        );
    }
}
