//! Pins for the observability subsystem (`obs`): span coverage and
//! nesting in the traced modes, Chrome-trace export fidelity, profile
//! agreement across backends — and, most load-bearing, the
//! `TraceMode::Off` overhead contract: an untraced plan allocates no
//! sink, takes no lock, and serves **bit-identical** outputs to both a
//! traced twin and a plan built through the pre-instrumentation
//! constructors (the PR 5 zero-alloc counter assertions, extended to
//! the tracing layer).

use std::collections::HashMap;

use tensorcalc::eval::Env;
use tensorcalc::exec::{BackendKind, CompiledPlan, EpilogueMode, ExecMemory};
use tensorcalc::ir::{Elem, Graph, NodeId};
use tensorcalc::obs::{chrome_trace_json, Profile, SpanKind, Trace, TraceMode};
use tensorcalc::problems::{logistic_regression, neural_net};
use tensorcalc::tensor::Tensor;

/// Compile the logreg value+gradient workload with explicit backend and
/// trace mode (planned memory, fusion on — the serving configuration).
fn logreg_plan(
    m: usize,
    n: usize,
    backend: BackendKind,
    trace: TraceMode,
) -> (CompiledPlan, Env) {
    let mut w = logistic_regression(m, n);
    let grad = w.gradient();
    let plan = plan_with(&w.g, &[w.loss, grad], EpilogueMode::default(), backend, trace);
    (plan, w.env)
}

fn plan_with(
    g: &Graph,
    roots: &[NodeId],
    epilogue: EpilogueMode,
    backend: BackendKind,
    trace: TraceMode,
) -> CompiledPlan {
    CompiledPlan::with_options(
        g,
        roots,
        true,
        epilogue,
        ExecMemory::default(),
        backend,
        trace,
    )
}

/// Instruction-span ids → occurrence counts for one drained trace.
fn instr_counts(trace: &Trace) -> HashMap<u32, u64> {
    let mut counts = HashMap::new();
    for s in trace.spans_of(SpanKind::Instr) {
        *counts.entry(s.id).or_insert(0u64) += 1;
    }
    counts
}

/// Profile mode: every executed instruction of the plan appears exactly
/// once in the drained trace — no more, no less — on both backends, and
/// the rolled-up `Profile` reports full coverage with no drops.
#[test]
fn profile_covers_every_executed_instruction_exactly_once() {
    for backend in [BackendKind::Cpu, BackendKind::Direct] {
        let (plan, env) = logreg_plan(48, 12, backend, TraceMode::Profile);
        let (outs, trace) = plan.run_traced(&env);
        assert_eq!(outs.len(), 2);
        assert_eq!(trace.mode, TraceMode::Profile);
        assert_eq!(trace.dropped, 0, "{:?}: pre-sized rings must not wrap", backend);

        let info = plan.plan_info();
        assert_eq!(info.instrs.len(), plan.executed_instrs());
        let counts = instr_counts(&trace);
        assert_eq!(
            counts.len(),
            plan.executed_instrs(),
            "{:?}: every executed instruction must be spanned",
            backend
        );
        for i in &info.instrs {
            assert_eq!(
                counts.get(&i.pos),
                Some(&1),
                "{:?}: instruction {} ({}) must record exactly one span",
                backend,
                i.pos,
                i.name
            );
        }

        let prof = Profile::build(&trace, &info);
        assert_eq!(prof.covered, prof.expected);
        assert_eq!(prof.dropped, 0);
        assert!(prof.wall_secs > 0.0);
        // every instruction row renders; the table is the CLI surface
        let table = prof.render_table(info.instrs.len());
        for i in &info.instrs {
            assert!(table.contains(&i.name), "{:?}: table lost {}", backend, i.name);
        }
    }
}

/// Warm traced re-runs stay covered: the sink is reset, not
/// re-allocated, and still records every instruction each run.
#[test]
fn warm_traced_reruns_reset_the_sink() {
    let (plan, env) = logreg_plan(32, 8, BackendKind::Cpu, TraceMode::Profile);
    let (_, first) = plan.run_traced(&env);
    for _ in 0..3 {
        let (_, again) = plan.run_traced(&env);
        assert_eq!(
            instr_counts(&again).len(),
            instr_counts(&first).len(),
            "a warm traced run must re-cover the full instruction stream"
        );
        assert_eq!(again.dropped, 0);
    }
    let st = plan.pool_stats();
    assert_eq!(st.trace_allocs, 1, "one sink per run state, reused across runs: {:?}", st);
}

/// The Chrome-trace export carries exactly the instruction stream: one
/// `"cat":"instr"` complete event per executed instruction, metadata
/// per lane, balanced braces, and the plan's backend in `otherData`.
#[test]
fn chrome_trace_json_matches_the_instruction_stream() {
    for backend in [BackendKind::Cpu, BackendKind::Direct] {
        let (plan, env) = logreg_plan(48, 12, backend, TraceMode::Trace);
        let (_, trace) = plan.run_traced(&env);
        let info = plan.plan_info();
        let js = chrome_trace_json(&trace, &info);

        assert!(js.starts_with("{\"traceEvents\":["), "{:?}: not a traceEvents object", backend);
        assert!(js.trim_end().ends_with('}'));
        assert_eq!(js.matches('{').count(), js.matches('}').count(), "{:?}", backend);
        assert_eq!(js.matches('[').count(), js.matches(']').count(), "{:?}", backend);
        assert_eq!(
            js.matches("\"cat\":\"instr\"").count(),
            plan.executed_instrs(),
            "{:?}: one instr event per executed instruction",
            backend
        );
        assert_eq!(
            js.matches("\"cat\":\"level\"").count(),
            trace.spans_of(SpanKind::Level).count(),
            "{:?}",
            backend
        );
        assert_eq!(js.matches("\"ph\":\"M\"").count(), trace.lanes);
        assert!(js.contains(&format!("\"backend\":\"{}\"", info.backend)));
        assert!(js.contains("\"mode\":\"trace\""));
        // every instruction position survives the export
        for i in &info.instrs {
            let needle = format!("\"pos\":{}", i.pos);
            assert!(js.contains(&needle), "{:?}: lost pos {}", backend, i.pos);
        }
    }
}

/// Both backends execute the same lowered stream, so their profiles
/// must agree exactly on the cost model's totals.
#[test]
fn cpu_and_direct_profiles_agree_on_flop_totals() {
    let mut w = neural_net(6, 4, 10);
    let h = w.hessian();
    let mut totals = Vec::new();
    for backend in [BackendKind::Cpu, BackendKind::Direct] {
        let plan =
            plan_with(&w.g, &[w.loss, h], EpilogueMode::default(), backend, TraceMode::Profile);
        let (_, trace) = plan.run_traced(&w.env);
        let prof = Profile::build(&trace, &plan.plan_info());
        assert_eq!(prof.covered, prof.expected, "{:?}", backend);
        totals.push(prof.total_flops);
    }
    assert!(totals[0] > 0, "the cost model must attribute work to this plan");
    assert_eq!(totals[0], totals[1], "backends disagree on total flops");
}

/// Full-timeline mode: every instruction span nests inside the span of
/// the level that scheduled it, on both backends.
#[test]
fn trace_mode_spans_nest_within_their_levels() {
    for backend in [BackendKind::Cpu, BackendKind::Direct] {
        let (plan, env) = logreg_plan(48, 12, backend, TraceMode::Trace);
        let (_, trace) = plan.run_traced(&env);
        let info = plan.plan_info();
        let level_of: HashMap<u32, u32> = info.instrs.iter().map(|i| (i.pos, i.level)).collect();
        let levels: HashMap<u32, (u64, u64)> = trace
            .spans_of(SpanKind::Level)
            .map(|s| (s.id, (s.t0_ns, s.t1_ns)))
            .collect();
        assert!(!levels.is_empty(), "{:?}: Trace mode must record level spans", backend);
        for s in trace.spans_of(SpanKind::Instr) {
            let lv = level_of[&s.id];
            let (l0, l1) = levels[&lv];
            assert!(
                l0 <= s.t0_ns && s.t1_ns <= l1,
                "{:?}: instr {} [{}, {}] escapes level {} [{}, {}]",
                backend,
                s.id,
                s.t0_ns,
                s.t1_ns,
                lv,
                l0,
                l1
            );
        }
    }
}

/// Two-pass epilogues show up as sub-spans nested inside the carrying
/// contraction's instruction span (cpu backend; the direct backend
/// bakes epilogues into its closures and records no sub-span).
#[test]
fn two_pass_epilogue_spans_nest_in_their_instruction() {
    let n = 64usize;
    let mut g = Graph::new();
    let x = g.var("X", &[n, n]);
    let wv = g.var("W", &[n, n]);
    let xw = g.matmul(x, wv);
    let t = g.elem(Elem::Tanh, xw);
    let one = g.constant(1.0, &[n, n]);
    let s = g.add(t, one);
    let y = g.hadamard(s, xw);
    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[n, n], 5));
    env.insert("W", Tensor::randn(&[n, n], 6));

    let plan = plan_with(&g, &[y], EpilogueMode::TwoPass, BackendKind::Cpu, TraceMode::Trace);
    let (_, trace) = plan.run_traced(&env);
    let epilogues: Vec<_> = trace.spans_of(SpanKind::Epilogue).copied().collect();
    assert!(!epilogues.is_empty(), "TwoPass + fusion must produce epilogue spans");
    for e in &epilogues {
        let host = trace
            .spans_of(SpanKind::Instr)
            .find(|s| s.id == e.id)
            .expect("epilogue span without its carrying instruction");
        assert!(
            host.t0_ns <= e.t0_ns && e.t1_ns <= host.t1_ns,
            "epilogue of instr {} escapes its instruction span",
            e.id
        );
        assert_eq!(host.lane, e.lane, "the second pass runs on the recording lane");
    }
}

/// The overhead contract. An untraced plan must (a) never allocate a
/// trace sink, (b) keep the PR 5 steady state — one cold arena
/// allocation, zero pool locks — across many warm runs, and (c) serve
/// outputs bit-identical to a Profile-mode twin *and* to a plan built
/// through the pre-instrumentation constructor path.
#[test]
fn off_mode_allocates_nothing_and_stays_bit_identical() {
    let (off, env) = logreg_plan(48, 12, BackendKind::Cpu, TraceMode::Off);
    assert_eq!(off.trace_mode(), TraceMode::Off);
    let baseline = off.run(&env);
    for _ in 0..20 {
        let again = off.run(&env);
        for (a, b) in baseline.iter().zip(&again) {
            assert_eq!(a.data(), b.data(), "untraced warm re-run drifted");
        }
    }
    let st = off.pool_stats();
    assert_eq!(st.trace_allocs, 0, "Off mode must never allocate a sink: {:?}", st);
    assert_eq!(st.arena_allocs, 1, "steady state regressed to re-allocating: {:?}", st);
    assert_eq!(st.pool_locks, 0, "steady state took the pool mutex: {:?}", st);

    // run_traced on an Off plan degrades to a plain run + empty trace
    let (outs, trace) = off.run_traced(&env);
    assert!(trace.spans.is_empty());
    for (a, b) in baseline.iter().zip(&outs) {
        assert_eq!(a.data(), b.data());
    }

    // tracing is read-only: a Profile twin computes the same bits
    let (profiled, _) = logreg_plan(48, 12, BackendKind::Cpu, TraceMode::Profile);
    let traced_out = profiled.run(&env);
    for (a, b) in baseline.iter().zip(&traced_out) {
        assert_eq!(a.data(), b.data(), "Profile mode perturbed the computation");
    }

    // and the pre-PR constructor compiles to the same results
    let mut w = logistic_regression(48, 12);
    let grad = w.gradient();
    let legacy = CompiledPlan::new(&w.g, &[w.loss, grad]).run(&w.env);
    for (a, b) in baseline.iter().zip(&legacy) {
        assert_eq!(a.data(), b.data(), "Off-mode plan diverged from the legacy constructor");
    }
}

/// The plan cache keys on trace mode: asking for a traced plan must not
/// hand back (or overwrite) the untraced artifact.
#[test]
fn plan_cache_separates_trace_modes() {
    use std::sync::Arc;
    use tensorcalc::exec::global_plan_cache;
    use tensorcalc::opt::OptLevel;

    let mut w = logistic_regression(24, 6);
    let grad = w.gradient();
    let roots = [w.loss, grad];
    let get = |trace: TraceMode| {
        global_plan_cache().get_or_compile_opts(
            &w.g,
            &roots,
            OptLevel::Full,
            ExecMemory::default(),
            BackendKind::default(),
            trace,
        )
    };
    let off = get(TraceMode::Off);
    let prof = get(TraceMode::Profile);
    assert!(!Arc::ptr_eq(&off, &prof), "cache conflated trace modes");
    assert_eq!(off.trace_mode(), TraceMode::Off);
    assert_eq!(prof.trace_mode(), TraceMode::Profile);
    assert!(Arc::ptr_eq(&off, &get(TraceMode::Off)), "same-mode lookup must hit");
    assert!(Arc::ptr_eq(&prof, &get(TraceMode::Profile)));
}
