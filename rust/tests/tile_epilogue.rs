//! Differential pinning of the tiled GEMM and its in-tile epilogue:
//!
//! * epilogue-free contractions: tiled default vs the flat reference
//!   kernel vs `einsum_naive` vs the interpreter, across skinny /
//!   square / panel / block-boundary shapes,
//! * contraction-fed fused chains: `EpilogueMode::InTile` vs
//!   `EpilogueMode::TwoPass` (bit-identical by contract) vs the unfused
//!   executor vs the interpreter,
//! * the matvec and batched fast paths with epilogues riding on them.

use tensorcalc::einsum::{einsum_naive, gemm_into_flat, EinSpec};
use tensorcalc::eval::{Env, Plan};
use tensorcalc::exec::{BackendKind, CompiledPlan, EpilogueMode, ExecMemory};
use tensorcalc::ir::{Elem, Graph, NodeId};
use tensorcalc::obs::TraceMode;
use tensorcalc::tensor::Tensor;

/// Shapes chosen to hit every kernel path: the flat small/skinny
/// fallback, the tiled serial path, block-boundary crossings (MC=64,
/// KC=256, NC=512 plus one), and the parallel row-band split.
const SHAPES: &[(usize, usize, usize)] = &[
    (64, 64, 64),    // square, tiled
    (65, 257, 130),  // one past every block boundary
    (4, 300, 1000),  // minimal tile rows, wide panel
    (3, 200, 130),   // skinny m — flat fallback
    (200, 3, 200),   // skinny k — tiled, kc = 3
    (512, 64, 16),   // tall panel
    (200, 200, 200), // parallel row bands
];

#[test]
fn epilogue_free_gemm_tiled_vs_flat_vs_naive() {
    for &(m, k, n) in SHAPES {
        let spec = EinSpec::parse("ij,jk->ik");
        let a = Tensor::randn(&[m, k], 7);
        let b = Tensor::randn(&[k, n], 8);
        let naive = einsum_naive(&spec, &a, &b);

        let mut flat = vec![0.0; m * n];
        gemm_into_flat(a.data(), b.data(), &mut flat, m, k, n);
        let flat = Tensor::new(&[m, n], flat);
        assert!(
            flat.allclose(&naive, 1e-9, 1e-9),
            "{m}x{k}x{n}: flat vs naive diff {}",
            flat.max_abs_diff(&naive)
        );

        let mut g = Graph::new();
        let av = g.var("A", &[m, k]);
        let bv = g.var("B", &[k, n]);
        let y = g.matmul(av, bv);
        let mut env = Env::new();
        env.insert("A", a);
        env.insert("B", b);
        let compiled = CompiledPlan::new(&g, &[y]).run(&env);
        let interp = Plan::new(&g, &[y]).run(&g, &env);
        assert!(
            compiled[0].allclose(&naive, 1e-9, 1e-9),
            "{m}x{k}x{n}: tiled vs naive diff {}",
            compiled[0].max_abs_diff(&naive)
        );
        assert!(
            compiled[0].allclose(&interp[0], 1e-12, 1e-12),
            "{m}x{k}x{n}: compiled vs interpreter diff {}",
            compiled[0].max_abs_diff(&interp[0])
        );
    }
}

/// `tanh(X·W) + 1`, then a Hadamard with the contraction output itself:
/// the fusion pass melts the whole chain into an epilogue whose carrier
/// (the `Mul`) is loaded twice.
fn chain_on_matmul(m: usize, k: usize, n: usize) -> (Graph, NodeId, Env) {
    let mut g = Graph::new();
    let x = g.var("X", &[m, k]);
    let w = g.var("W", &[k, n]);
    let xw = g.matmul(x, w);
    let t = g.elem(Elem::Tanh, xw);
    let one = g.constant(1.0, &[m, n]);
    let s = g.add(t, one);
    let y = g.hadamard(s, xw);
    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[m, k], 21));
    env.insert("W", Tensor::randn(&[k, n], 22));
    (g, y, env)
}

#[test]
fn in_tile_epilogue_pinned_on_all_shapes() {
    for &(m, k, n) in SHAPES {
        let (g, y, env) = chain_on_matmul(m, k, n);
        let in_tile = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::InTile,
            ExecMemory::Planned,
            BackendKind::default(),
            TraceMode::Off,
        );
        let two_pass = CompiledPlan::with_options(
            &g,
            &[y],
            true,
            EpilogueMode::TwoPass,
            ExecMemory::Planned,
            BackendKind::default(),
            TraceMode::Off,
        );
        let unfused = CompiledPlan::with_fusion(&g, &[y], false);
        assert!(
            in_tile.fused_count() >= 1,
            "{m}x{k}x{n}: the chain must fuse into an epilogue"
        );
        assert!(in_tile.len() < unfused.len());

        let a = in_tile.run(&env);
        let b = two_pass.run(&env);
        let c = unfused.run(&env);
        let want = Plan::new(&g, &[y]).run(&g, &env);
        assert_eq!(
            a[0].data(),
            b[0].data(),
            "{m}x{k}x{n}: in-tile vs two-pass must be bit-identical"
        );
        assert_eq!(
            a[0].data(),
            c[0].data(),
            "{m}x{k}x{n}: epilogue vs unfused must be bit-identical"
        );
        assert!(
            a[0].allclose(&want[0], 1e-12, 1e-12),
            "{m}x{k}x{n}: vs interpreter diff {}",
            a[0].max_abs_diff(&want[0])
        );
    }
}

#[test]
fn in_tile_epilogue_on_matvec_fast_path() {
    // n = 1 takes the matvec kernel; 300×700 crosses the parallel gate
    let (m, k) = (300usize, 700usize);
    let mut g = Graph::new();
    let x = g.var("X", &[m, k]);
    let w = g.var("w", &[k]);
    let xw = g.matvec(x, w);
    let t = g.elem(Elem::Sigmoid, xw);
    let y = g.scale(t, 0.5);
    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[m, k], 31));
    env.insert("w", Tensor::randn(&[k], 32));
    let in_tile = CompiledPlan::with_options(
        &g,
        &[y],
        true,
        EpilogueMode::InTile,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    let two_pass = CompiledPlan::with_options(
        &g,
        &[y],
        true,
        EpilogueMode::TwoPass,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    assert!(in_tile.fused_count() >= 1);
    let a = in_tile.run(&env);
    let b = two_pass.run(&env);
    let want = Plan::new(&g, &[y]).run(&g, &env);
    assert_eq!(a[0].data(), b[0].data());
    assert!(a[0].allclose(&want[0], 1e-12, 1e-12));
}

#[test]
fn in_tile_epilogue_on_batched_contraction() {
    // 300 batch slices of 8×8×8 take the parallel batch split (slice
    // flops below PAR_BATCH_SLICE_MAX_FLOP, total above
    // PAR_BATCH_TOTAL_MIN_FLOP); the epilogue's global offsets must
    // line up across slices
    let (bsz, d) = (300usize, 8usize);
    let mut g = Graph::new();
    let a = g.var("A", &[bsz, d, d]);
    let b = g.var("B", &[bsz, d, d]);
    let ab = g.mul(a, b, EinSpec::parse("aij,ajk->aik"));
    let t = g.elem(Elem::Tanh, ab);
    let y = g.scale(t, 2.0);
    let mut env = Env::new();
    env.insert("A", Tensor::randn(&[bsz, d, d], 51));
    env.insert("B", Tensor::randn(&[bsz, d, d], 52));
    let in_tile = CompiledPlan::with_options(
        &g,
        &[y],
        true,
        EpilogueMode::InTile,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    let two_pass = CompiledPlan::with_options(
        &g,
        &[y],
        true,
        EpilogueMode::TwoPass,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    assert!(in_tile.fused_count() >= 1);
    let va = in_tile.run(&env);
    let vb = two_pass.run(&env);
    let want = Plan::new(&g, &[y]).run(&g, &env);
    assert_eq!(va[0].data(), vb[0].data());
    assert!(va[0].allclose(&want[0], 1e-12, 1e-12));
}

#[test]
fn in_tile_epilogue_on_permuted_output_falls_back() {
    // "ij,jk->ki" permutes the GEMM product: the epilogue must run on
    // the permuted output (the fallback), not inside the tiles
    let (m, k, n) = (65usize, 257, 130);
    let mut g = Graph::new();
    let a = g.var("A", &[m, k]);
    let b = g.var("B", &[k, n]);
    let ab = g.mul(a, b, EinSpec::parse("ij,jk->ki"));
    let y = g.elem(Elem::Tanh, ab);
    let mut env = Env::new();
    env.insert("A", Tensor::randn(&[m, k], 61));
    env.insert("B", Tensor::randn(&[k, n], 62));
    let in_tile = CompiledPlan::with_options(
        &g,
        &[y],
        true,
        EpilogueMode::InTile,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    let two_pass = CompiledPlan::with_options(
        &g,
        &[y],
        true,
        EpilogueMode::TwoPass,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    let va = in_tile.run(&env);
    let vb = two_pass.run(&env);
    let want = Plan::new(&g, &[y]).run(&g, &env);
    assert_eq!(va[0].data(), vb[0].data());
    assert!(va[0].allclose(&want[0], 1e-12, 1e-12));
}
