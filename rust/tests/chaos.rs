//! Chaos suite: the serving coordinator's robustness invariants under
//! deterministic fault injection ([`tensorcalc::coordinator::FaultPlan`]).
//!
//! Every test pins the same four contracts from ARCHITECTURE.md
//! ("Serving robustness"), under a different fault mix:
//!
//! 1. **One answer per request** — every accepted submission is resolved
//!    exactly once: a reply, a typed error, or a dropped channel
//!    (`RecvError`). Never a hang.
//! 2. **Shutdown terminates** — `Coordinator::shutdown` joins every
//!    worker even while faults are firing, and answers jobs accepted
//!    before the close.
//! 3. **The balance holds** — `submitted == completed + errors + shed +
//!    expired` over admitted requests, under every fault mix (admission
//!    rejections are counted separately and sit outside the balance).
//! 4. **Degraded output is bit-identical** — the degradation ladder
//!    changes scheduling, never numerics.
//!
//! The fault seed comes from `TC_FAULT_SEED` (default 1), so CI can
//! sweep seeds while any one run stays exactly reproducible.

use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use tensorcalc::coordinator::{
    Coordinator, EngineEntry, FaultPlan, FaultSite, Request, ServeError, ServeResult,
    ShedPolicy, Snapshot, SubmitError,
};
use tensorcalc::problems::logistic_regression;
use tensorcalc::tensor::Tensor;

/// Fault seed for this run: `TC_FAULT_SEED` env, default 1. CI sweeps a
/// small seed matrix; locally `TC_FAULT_SEED=7 cargo test --test chaos`
/// replays one schedule exactly.
fn seed() -> u64 {
    std::env::var("TC_FAULT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(1)
}

/// A logistic-regression gradient entry (the serving workload used
/// throughout `tests/serve_batch.rs`): inputs X [6,3], y [6], w [3];
/// roots [loss, grad].
fn logreg_entry() -> EngineEntry {
    let mut wl = logistic_regression(6, 3);
    let grad = wl.gradient();
    let roots = vec![wl.loss, grad];
    EngineEntry::compiled(
        &wl.g,
        &roots,
        vec![
            ("X".into(), vec![6, 3]),
            ("y".into(), vec![6]),
            ("w".into(), vec![3]),
        ],
    )
}

fn inputs(s: u64) -> Vec<Tensor> {
    vec![
        Tensor::randn(&[6, 3], 3000 + s),
        Tensor::randn(&[6], 5000 + s).map(f64::signum),
        Tensor::randn(&[3], 7000 + s),
    ]
}

/// Contract 3: the accounting balance over *admitted* requests.
fn assert_balance(snap: &Snapshot) {
    assert_eq!(
        snap.submitted,
        snap.completed + snap.errors + snap.shed + snap.expired,
        "balance violated: {:?}",
        snap
    );
}

/// Contract 1: resolve one receiver within a generous bound — a reply,
/// a serve error, or a dropped channel. A timeout is a hang, and fails.
fn resolve(rx: &std::sync::mpsc::Receiver<ServeResult>) -> Option<ServeResult> {
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(r) => Some(r),
        Err(RecvTimeoutError::Disconnected) => None,
        Err(RecvTimeoutError::Timeout) => panic!("request hung: no reply within 30s"),
    }
}

/// Exec panics are caught per chunk, answered as retryable
/// `ServeError::Panic`, and never kill the worker — and the balance
/// holds over the mixed ok/panic outcome stream.
#[test]
fn injected_exec_panics_are_isolated_and_balanced() {
    let faults = FaultPlan::seeded(seed()).with_rate(FaultSite::ExecPanic, 0.3);
    let mut c = Coordinator::with_faults(256, faults);
    // max_batch 1: one chunk (= one panic draw) per request, so 60
    // draws at rate 0.3 make both outcomes overwhelmingly certain for
    // any seed
    c.register_engine("grad", logreg_entry().with_max_batch(1));

    let rxs: Vec<_> =
        (0..60).map(|s| c.submit("grad", inputs(s)).expect("queue has room")).collect();
    let (mut ok, mut panicked) = (0u64, 0u64);
    for rx in &rxs {
        match resolve(rx).expect("exec-panic faults never drop replies") {
            Ok(resp) => {
                assert_eq!(resp.outputs.len(), 2);
                ok += 1;
            }
            Err(ServeError::Panic(msg)) => {
                assert!(msg.contains("injected fault"), "unexpected panic: {}", msg);
                assert!(ServeError::Panic(msg).is_retryable());
                panicked += 1;
            }
            Err(other) => panic!("unexpected serve error: {}", other),
        }
    }
    c.shutdown();

    assert!(ok > 0, "rate 0.3 must let some requests through");
    assert!(panicked > 0, "rate 0.3 must fire over 60 draws");
    let snap = c.metrics().snapshot();
    assert_eq!(snap.submitted, 60);
    assert_eq!(snap.completed, ok);
    assert_eq!(snap.errors, panicked);
    assert_balance(&snap);
}

/// Under sustained overload with `ShedPolicy::ShedOldest`, every
/// submission is accepted, victims are answered `Err(Shed)` (retryable),
/// and sheds are counted inside the balance.
#[test]
fn overload_sheds_oldest_and_answers_every_victim() {
    let faults = FaultPlan::seeded(seed())
        .with_rate(FaultSite::ServiceLatency, 1.0)
        .with_latency(Duration::from_millis(10));
    let mut c = Coordinator::with_faults(2, faults);
    c.register_engine(
        "grad",
        logreg_entry().with_max_batch(1).with_shed_policy(ShedPolicy::ShedOldest),
    );

    // cap-2 queue, 10ms of injected latency per chunk, 40 rapid
    // submissions: the queue must evict
    let rxs: Vec<_> = (0..40)
        .map(|s| c.submit("grad", inputs(s)).expect("shed-oldest always accepts"))
        .collect();
    let (mut ok, mut shed) = (0u64, 0u64);
    for rx in &rxs {
        match resolve(rx).expect("shed faults never drop replies") {
            Ok(_) => ok += 1,
            Err(ServeError::Shed) => {
                assert!(ServeError::Shed.is_retryable());
                shed += 1;
            }
            Err(other) => panic!("unexpected serve error: {}", other),
        }
    }
    c.shutdown();

    assert_eq!(ok + shed, 40, "every submission resolves exactly once");
    assert!(shed > 0, "a cap-2 queue under 40 rapid submits must shed");
    let snap = c.metrics().snapshot();
    assert_eq!(snap.submitted, 40);
    assert_eq!(snap.shed, shed);
    assert_balance(&snap);
}

/// Injected queue-full faults surface as typed, retryable
/// `SubmitError::QueueFull`; rejections are counted outside the balance,
/// which still holds over the requests that were admitted.
#[test]
fn injected_queue_full_rejections_are_typed_and_outside_the_balance() {
    let faults = FaultPlan::seeded(seed()).with_rate(FaultSite::QueueFull, 0.5);
    let mut c = Coordinator::with_faults(256, faults);
    c.register_engine("grad", logreg_entry());

    let mut rxs = Vec::new();
    let mut rejected = 0u64;
    for s in 0..100 {
        match c.submit("grad", inputs(s)) {
            Ok(rx) => rxs.push(rx),
            Err(e) => {
                assert_eq!(e, SubmitError::QueueFull { entry: "grad".into() });
                assert!(e.is_retryable());
                rejected += 1;
            }
        }
    }
    for rx in &rxs {
        assert!(
            resolve(rx).expect("no reply-drop faults in this mix").is_ok(),
            "admitted requests must serve normally"
        );
    }
    c.shutdown();

    assert!(rejected > 0, "rate 0.5 must reject over 100 draws");
    assert!(!rxs.is_empty(), "rate 0.5 must admit over 100 draws");
    let snap = c.metrics().snapshot();
    assert_eq!(snap.rejected_full, rejected);
    assert_eq!(snap.submitted, rxs.len() as u64);
    assert_eq!(snap.completed, rxs.len() as u64);
    assert_balance(&snap);
}

/// A dropped reply channel resolves the caller with `RecvError` — never
/// a hang — and the request was already counted, so the balance
/// survives the drop.
#[test]
fn dropped_replies_disconnect_instead_of_hanging() {
    let faults = FaultPlan::seeded(seed()).with_rate(FaultSite::ReplyDrop, 1.0);
    let mut c = Coordinator::with_faults(64, faults);
    c.register_engine("grad", logreg_entry());

    let rxs: Vec<_> =
        (0..10).map(|s| c.submit("grad", inputs(s)).expect("queue has room")).collect();
    for rx in &rxs {
        assert!(
            resolve(rx).is_none(),
            "reply_drop=1.0 must drop every channel (disconnect, not hang)"
        );
    }
    c.shutdown();

    let snap = c.metrics().snapshot();
    assert_eq!(snap.submitted, 10);
    assert_eq!(
        snap.completed, 10,
        "dropped replies are counted before the drop — accounting is not lost"
    );
    assert_balance(&snap);
}

/// Deadlines: already-expired at submit → rejected before the queue
/// (outside the balance); expiring while queued behind a slow chunk →
/// answered `Err(Expired)` before any exec work (inside the balance).
#[test]
fn expired_deadlines_are_refused_or_answered_before_exec() {
    let faults = FaultPlan::seeded(seed())
        .with_rate(FaultSite::ServiceLatency, 1.0)
        .with_latency(Duration::from_millis(300));
    let mut c = Coordinator::with_faults(64, faults);
    c.register_engine("grad", logreg_entry().with_max_batch(1));

    // (a) dead on arrival: a zero budget has already expired by the
    // time admission checks it
    for s in 0..3 {
        let req = Request::new(inputs(s)).with_deadline(Duration::ZERO);
        match c.submit_with("grad", req) {
            Err(e @ SubmitError::Expired { .. }) => assert!(!e.is_retryable()),
            other => panic!("expected Expired at admission, got {:?}", other),
        }
    }

    // (b) expiry in the queue: the nearest-deadline job runs first and
    // its chunk carries 300ms of injected latency, so the 250ms-deadline
    // job expires before its turn — whether the worker drains the two
    // jobs together (mid-drain re-check) or one at a time (pre-drain
    // check), the outcome is the same
    let served = c
        .submit_with(
            "grad",
            Request::new(inputs(10)).with_deadline(Duration::from_millis(100)),
        )
        .expect("future deadline is admitted");
    let doomed = c
        .submit_with(
            "grad",
            Request::new(inputs(11)).with_deadline(Duration::from_millis(250)),
        )
        .expect("future deadline is admitted");
    assert!(
        resolve(&served).expect("no reply drops").is_ok(),
        "the nearest-deadline job runs before its deadline check can fail"
    );
    match resolve(&doomed).expect("expiry faults never drop replies") {
        Err(ServeError::Expired) => {}
        other => panic!("expected Err(Expired), got {:?}", other),
    }
    c.shutdown();

    let snap = c.metrics().snapshot();
    assert_eq!(snap.rejected_expired, 3, "dead-on-arrival requests never enter the queue");
    assert_eq!(snap.expired, 1);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.submitted, 2, "rejected submissions are not counted as submitted");
    assert_balance(&snap);
}

/// Contract 4: degraded serving (levels 1 and 2) returns bit-identical
/// outputs to the undegraded entry — the ladder changes scheduling,
/// never numerics.
#[test]
fn degraded_serving_is_bit_identical_to_normal() {
    // reference: no faults, no degradation
    let mut reference = Coordinator::with_faults(64, FaultPlan::none());
    reference.register_engine("ref", logreg_entry().with_prewarm(true));

    // degraded: injected latency builds real queue depth so level-1
    // drains actually take multi-request chunks through the exact-fit
    // compiled buckets
    let faults = FaultPlan::seeded(seed())
        .with_rate(FaultSite::ServiceLatency, 1.0)
        .with_latency(Duration::from_millis(5));
    let mut degraded = Coordinator::with_faults(64, faults);
    degraded.register_engine(
        "deg1",
        logreg_entry().with_prewarm(true).with_forced_degrade_level(1),
    );
    degraded.register_engine("deg2", logreg_entry().with_forced_degrade_level(2));

    let n = 12u64;
    let want: Vec<_> = (0..n)
        .map(|s| {
            let resp = reference.eval("ref", inputs(s)).expect("reference serves");
            resp.outputs.iter().map(|o| o.data().to_vec()).collect::<Vec<_>>()
        })
        .collect();

    for entry in ["deg1", "deg2"] {
        let rxs: Vec<_> = (0..n)
            .map(|s| degraded.submit(entry, inputs(s)).expect("queue has room"))
            .collect();
        for (s, rx) in rxs.iter().enumerate() {
            let resp = resolve(rx)
                .expect("no reply drops in this mix")
                .expect("degraded entries still serve");
            assert_eq!(resp.outputs.len(), want[s].len());
            for (r, w) in want[s].iter().enumerate() {
                assert_eq!(
                    resp.outputs[r].data(),
                    &w[..],
                    "{}: request {} root {} not bit-identical to normal serving",
                    entry,
                    s,
                    r
                );
            }
        }
    }
    degraded.shutdown();
    reference.shutdown();

    let snap = degraded.metrics().snapshot();
    assert!(snap.degraded > 0, "forced levels must count degraded chunks");
    assert_eq!(snap.completed, 2 * n);
    assert_balance(&snap);
}

/// Contract 2: shutdown terminates under a storm on every fault site at
/// once, every accepted request still resolves (reply, error, or
/// disconnect), and the balance holds over whatever mix the storm
/// produced.
#[test]
fn shutdown_terminates_under_a_fault_storm() {
    let faults = FaultPlan::seeded(seed())
        .with_rate(FaultSite::QueueFull, 0.2)
        .with_rate(FaultSite::ExecPanic, 0.2)
        .with_rate(FaultSite::ServiceLatency, 0.2)
        .with_rate(FaultSite::ReplyDrop, 0.2)
        .with_latency(Duration::from_millis(2));
    let mut c = Coordinator::with_faults(8, faults);
    c.register_engine(
        "grad",
        logreg_entry().with_max_batch(2).with_shed_policy(ShedPolicy::ShedOldest),
    );

    let mut rxs = Vec::new();
    for s in 0..40 {
        let req = if s % 4 == 0 {
            Request::new(inputs(s)).with_deadline(Duration::from_millis(30))
        } else {
            Request::new(inputs(s))
        };
        match c.submit_with("grad", req) {
            Ok(rx) => rxs.push(rx),
            Err(e) => assert!(
                matches!(e, SubmitError::QueueFull { .. } | SubmitError::Expired { .. }),
                "storm admission can only refuse full/expired, got {:?}",
                e
            ),
        }
    }
    let accepted = rxs.len() as u64;

    // watchdog: shutdown on its own thread; polling join guards against
    // a wedged worker turning the suite into a hang
    let metrics = c.metrics();
    let h = std::thread::spawn(move || c.shutdown());
    let t0 = Instant::now();
    while !h.is_finished() {
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "shutdown wedged under fault storm"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    h.join().expect("shutdown thread must not panic");

    // every accepted request resolves exactly once — reply, typed
    // error, or disconnect — even though shutdown already completed
    let mut resolved = 0u64;
    for rx in &rxs {
        let _ = resolve(rx);
        resolved += 1;
    }
    assert_eq!(resolved, accepted);

    let snap = metrics.snapshot();
    assert_eq!(snap.submitted, accepted);
    assert_balance(&snap);
}
