//! The SIMD differential wall: forced-scalar vs every dispatched ISA vs
//! the interpreter oracle, across the whole execution option matrix.
//!
//! The kernel contract under test (see `util::simd`):
//!
//! * every SIMD microkernel maps lanes across the `NR` column dimension
//!   and uses separate mul-then-add (never FMA), so each `C[r][j]` keeps
//!   the scalar kernel's k-accumulation chain — forced-scalar and every
//!   dispatched tier must be **bit-identical**, not merely close;
//! * the dispatch is resolved per call from the process-global active
//!   ISA, so one compiled plan re-run under a flipped ISA takes the new
//!   kernels — the wall compiles each cell once and sweeps ISAs over it;
//! * blocking geometry (`MR/NR/MC/NC`) never affects numerics and every
//!   autotune candidate shares `KC`, so the tuner's pick is invisible to
//!   these assertions;
//! * all of the above must hold in every `ExecMemory` × `EpilogueMode`
//!   × `BackendKind` cell, on the batched serving variant, and without
//!   disturbing the zero-alloc / no-lock steady state.
//!
//! Tests that flip the active ISA serialize on a process-wide mutex:
//! the ISA is process-global state and `cargo test` runs the tests in
//! this binary on several threads.

use std::sync::{Mutex, MutexGuard};

use tensorcalc::einsum::{gemm, gemm_into};
use tensorcalc::eval::{Env, Plan};
use tensorcalc::exec::{batch_graph, BackendKind, CompiledPlan, EpilogueMode, ExecMemory};
use tensorcalc::ir::{Graph, NodeId};
use tensorcalc::obs::TraceMode;
use tensorcalc::opt::{compact, optimize, OptLevel};
use tensorcalc::problems::{logistic_regression, matrix_factorization, neural_net};
use tensorcalc::tensor::{Tensor, XorShift};
use tensorcalc::util::simd::{blocking, set_isa, supported_isas, Blocking, Isa};

static ISA_LOCK: Mutex<()> = Mutex::new(());

fn isa_lock() -> MutexGuard<'static, ()> {
    // a failed assertion elsewhere must not wedge the rest of the wall
    ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// RAII flip of the process-global ISA; restores the previous tier on
/// drop so a failing assertion cannot leak a forced ISA into later
/// tests. Callers must already hold [`isa_lock`].
struct IsaFlip {
    prev: Isa,
}

impl IsaFlip {
    fn to(isa: Isa) -> IsaFlip {
        IsaFlip { prev: set_isa(isa) }
    }
}

impl Drop for IsaFlip {
    fn drop(&mut self) {
        set_isa(self.prev);
    }
}

/// One workload through the full option matrix. Each
/// memory × epilogue × backend cell is compiled **once**, then re-run
/// under forced scalar and under every dispatched ISA the CPU supports:
/// the scalar run must stay allclose to the interpreter oracle, and
/// every SIMD run must reproduce the scalar run bit for bit.
fn check_wall(g: &Graph, roots: &[NodeId], env: &Env, label: &str) {
    let oracle = Plan::new(g, roots).run(g, env);
    let isas = supported_isas();
    assert_eq!(isas[0], Isa::Scalar, "scalar must lead the ISA sweep");
    for memory in [ExecMemory::Planned, ExecMemory::Pooled] {
        for epilogue in [EpilogueMode::InTile, EpilogueMode::TwoPass] {
            for backend in [BackendKind::Cpu, BackendKind::Direct] {
                let plan = CompiledPlan::with_options(
                    g,
                    roots,
                    true,
                    epilogue,
                    memory,
                    backend,
                    TraceMode::Off,
                );
                let cell = format!("{label} [{:?}/{:?}/{:?}]", memory, epilogue, backend);
                let base = {
                    let _s = IsaFlip::to(Isa::Scalar);
                    plan.run(env)
                };
                assert_eq!(base.len(), oracle.len());
                for (k, (tb, tw)) in base.iter().zip(&oracle).enumerate() {
                    assert!(
                        tb.allclose(tw, 1e-9, 1e-11),
                        "{cell}: root {k}: forced scalar vs interpreter diff {}",
                        tb.max_abs_diff(tw)
                    );
                }
                for &isa in &isas[1..] {
                    let _s = IsaFlip::to(isa);
                    let got = plan.run(env);
                    for (k, (tg, tb)) in got.iter().zip(&base).enumerate() {
                        assert_eq!(
                            tg.data(),
                            tb.data(),
                            "{cell}: root {k}: {} must be bit-identical to forced scalar",
                            isa.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn gemm_bit_identical_across_isas() {
    // the kernel seam in isolation, below the executor: accumulating
    // GEMM on awkward shapes (m/n of 1, non-multiples of MR/NR, k both
    // under and over KC so multi-KC-block flushes are covered too)
    let _lock = isa_lock();
    let isas = supported_isas();
    let blk = blocking();
    let mut rng = XorShift::new(0x51D0);
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (1, 7, 64),
        (5, 300, 1),
        (37, 61, 29),
        (64, blk.kc, 48),
        (33, blk.kc + 17, 70),
        (96, 129, 131),
    ] {
        let a: Vec<f64> = (0..m * k).map(|_| rng.next_f64() - 0.5).collect();
        let b: Vec<f64> = (0..k * n).map(|_| rng.next_f64() - 0.5).collect();
        let base = {
            let _s = IsaFlip::to(Isa::Scalar);
            // non-zero C: the accumulate path is the contract
            let mut c: Vec<f64> = (0..m * n).map(|i| (i % 5) as f64 * 0.125).collect();
            gemm_into(&a, &b, &mut c, m, k, n);
            c
        };
        for &isa in &isas[1..] {
            let _s = IsaFlip::to(isa);
            let mut c: Vec<f64> = (0..m * n).map(|i| (i % 5) as f64 * 0.125).collect();
            gemm_into(&a, &b, &mut c, m, k, n);
            assert_eq!(
                c,
                base,
                "gemm {m}x{k}x{n}: {} diverged from forced scalar",
                isa.name()
            );
        }
        // and the scalar result itself is right: naive triple loop
        let mut want = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    want[i * n + j] += av * b[p * n + j];
                }
            }
        }
        for (i, (&got, &w)) in base.iter().zip(&want).enumerate() {
            let got = got - (i % 5) as f64 * 0.125;
            assert!(
                (got - w).abs() <= 1e-9 * w.abs().max(1.0),
                "gemm {m}x{k}x{n}: element {i}: scalar {got} vs naive {w}"
            );
        }
    }
    // gemm() (the allocating wrapper) rides the same seam
    let _s = IsaFlip::to(*isas.last().unwrap());
    let a = vec![1.0; 6];
    let b = vec![2.0; 6];
    assert_eq!(gemm(&a, &b, 2, 3, 2), vec![6.0; 4]);
}

#[test]
fn logreg_gradient_wall() {
    let _lock = isa_lock();
    let mut w = logistic_regression(96, 8);
    let grad = w.gradient();
    check_wall(&w.g, &[w.loss, grad], &w.env, "logreg-grad");
}

#[test]
fn matfac_compressed_hessian_wall() {
    // §3.3 compressed Hessian core: dense contraction chains over
    // shared sub-DAGs — the heaviest GEMM mix in the suite
    let _lock = isa_lock();
    let mut w = matrix_factorization(12, 12, 3, false);
    let comp = w.hessian_compressed();
    assert!(comp.is_compressed());
    let core = comp.eval_node();
    check_wall(&w.g, &[core], &w.env, "matfac-hess-compressed");
}

#[test]
fn neural_net_hessian_optimized_wall() {
    // reverse-over-reverse MLP Hessian after OptLevel::Full: the
    // deepest fused element-wise pipelines, so this cell exercises the
    // lane-chunked FusedKernel interpreter as hard as the microkernels
    let _lock = isa_lock();
    let mut w = neural_net(6, 4, 10);
    let h = w.hessian();
    let mut g2 = w.g.clone();
    let o = optimize(&mut g2, &[h], OptLevel::Full);
    check_wall(&g2, &o.roots, &w.env, "mlp-hess-opt");
}

#[test]
fn batched_serving_wall() {
    // the serving path's shape: canonicalise exactly as the engine
    // does (optimize → compact → batch_graph), sweep the batched graph
    // through the full wall, then check the dispatched-ISA batched
    // outputs still decompose into the per-request interpreter answers
    let _lock = isa_lock();
    let bsz = 4usize;
    let mut w = logistic_regression(8, 4);
    let grad = w.gradient();
    let roots = [w.loss, grad];
    let mut g2 = w.g.clone();
    let o = optimize(&mut g2, &roots, OptLevel::Full);
    let (gc, croots) = compact(&g2, &o.roots);
    let (bg, broots) = batch_graph(&gc, &croots, bsz);

    let vars: Vec<(String, Vec<usize>)> = w
        .g
        .var_names()
        .into_iter()
        .map(|n| {
            let id = w.g.var_id(&n).unwrap();
            (n, w.g.shape(id).to_vec())
        })
        .collect();
    let mut envs = Vec::new();
    for b in 0..bsz {
        let mut env = Env::new();
        for (i, (name, shape)) in vars.iter().enumerate() {
            let seed = 900 + (b * vars.len() + i) as u64;
            env.insert(name, Tensor::randn(shape, seed).scale(0.5));
        }
        envs.push(env);
    }
    let mut benv = Env::new();
    for (name, _) in &vars {
        let mut bshape = vec![bsz];
        let first = envs[0].get(name).unwrap();
        bshape.extend_from_slice(first.shape());
        let mut data = Vec::with_capacity(bsz * first.len());
        for e in &envs {
            data.extend_from_slice(e.get(name).unwrap().data());
        }
        benv.insert(name, Tensor::new(&bshape, data));
    }

    check_wall(&bg, &broots, &benv, "logreg-grad-batched");

    let bplan = CompiledPlan::with_backend(&bg, &broots, BackendKind::Direct);
    let interp = Plan::new(&w.g, &roots);
    for &isa in &supported_isas() {
        let _s = IsaFlip::to(isa);
        let batched = bplan.run(&benv);
        for (b, env) in envs.iter().enumerate() {
            let want_all = interp.run(&w.g, env);
            for (r, want) in want_all.iter().enumerate() {
                let len = want.len();
                let chunk = batched[r].data()[b * len..(b + 1) * len].to_vec();
                let slice = Tensor::new(want.shape(), chunk);
                assert!(
                    slice.allclose(want, 1e-9, 1e-11),
                    "{}: slice {b} of root {r} diverged from the per-request \
                     oracle, diff {}",
                    isa.name(),
                    slice.max_abs_diff(want)
                );
            }
        }
    }
}

#[test]
fn steady_state_stays_zero_alloc_under_simd() {
    // the dispatch indirection must not disturb the Off-trace steady
    // state: after warm-up under the widest dispatched ISA, re-runs
    // allocate no new arenas and never touch the pool mutex
    let _lock = isa_lock();
    let best = *supported_isas().last().unwrap();
    let _s = IsaFlip::to(best);
    let mut w = logistic_regression(48, 12);
    let grad = w.gradient();
    let plan = CompiledPlan::new(&w.g, &[w.loss, grad]);
    let first = plan.run(&w.env);
    let cold = plan.pool_stats();
    for _ in 0..5 {
        let again = plan.run(&w.env);
        assert_eq!(again[0].data(), first[0].data(), "warm re-run drifted under {best:?}");
        assert_eq!(again[1].data(), first[1].data());
    }
    let warm = plan.pool_stats();
    assert_eq!(
        warm.arena_allocs, cold.arena_allocs,
        "arena grew after warm-up under {best:?}: {:?}",
        warm
    );
    assert_eq!(
        warm.pool_locks, 0,
        "planned mode took the pool mutex under {best:?}: {:?}",
        warm
    );
}

#[test]
fn dispatch_surface_parses_and_validates() {
    // the knobs the wall (and CI) steer by: TC_SIMD names round-trip,
    // the active blocking is sane, and every supported tier is flippable
    let _lock = isa_lock();
    for isa in [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon] {
        assert_eq!(Isa::parse(isa.name()), Some(isa));
    }
    assert_eq!(Isa::parse("off"), Some(Isa::Scalar));
    assert_eq!(Isa::parse("AVX2"), Some(Isa::Avx2));
    assert_eq!(Isa::parse("sse9"), None);

    let blk = blocking();
    blk.validate().expect("the process blocking must validate");
    let spec = format!("{},{},{},{},{}", blk.mr, blk.nr, blk.mc, blk.kc, blk.nc);
    assert_eq!(Blocking::parse(&spec).unwrap(), blk, "blocking must round-trip via its spec");
    assert!(Blocking::parse("4,8,63,256,512").is_err(), "MC % MR != 0 must be rejected");

    for isa in supported_isas() {
        let prev = set_isa(isa);
        set_isa(prev);
    }
}
