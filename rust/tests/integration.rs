//! Cross-module integration tests: parser → autodiff → simplify →
//! cross-country → compress → eval, the three benchmark workloads, and
//! the coordinator + PJRT runtime (artifact-gated).

use tensorcalc::autodiff::hessian::grad_and_hessian;
use tensorcalc::baselines::PerEntryHessian;
use tensorcalc::coordinator::{Coordinator, EngineEntry};
use tensorcalc::eval::{eval, eval_many, fd_gradient, Env};
use tensorcalc::parser::{parse_expr, VarDecl};
use tensorcalc::prelude::*;
use tensorcalc::problems::{
    logistic_regression, matrix_factorization, neural_net, newton_step_compressed,
};
use tensorcalc::solve::solve_spd;
use tensorcalc::tensor::Tensor;

/// The full front-to-back path on the paper's Expression (1): parse,
/// differentiate, reorder, simplify; every stage must agree numerically.
#[test]
fn expression1_full_pipeline() {
    let decls = vec![VarDecl::new("X", &[6, 4]), VarDecl::new("w", &[4])];
    let mut g = Graph::new();
    let y = parse_expr(&mut g, &decls, "X'*(inv(exp(X*w)+1) .* exp(X*w))").unwrap();
    let w = g.var_id("w").unwrap();

    let jac_raw = reverse_derivative(&mut g, y, &[w])[0];
    let jac_simpl = simplify(&mut g, &[jac_raw])[0];
    let jac_cc = optimize_contractions(&mut g, jac_simpl);
    let jac_cc = simplify(&mut g, &[jac_cc])[0];
    let jac_fwd = forward_derivative(&mut g, y, w);

    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[6, 4], 1));
    env.insert("w", Tensor::randn(&[4], 2).scale(0.3));
    let vals = eval_many(&g, &[jac_raw, jac_simpl, jac_cc, jac_fwd], &env);
    for (i, v) in vals.iter().enumerate().skip(1) {
        assert!(
            v.allclose(&vals[0], 1e-9, 1e-11),
            "stage {} disagrees, diff {}",
            i,
            v.max_abs_diff(&vals[0])
        );
    }
}

/// A parsed loss drives a full Newton solve (parser + autodiff + solve).
#[test]
fn parsed_newton_on_ridge_regression() {
    // f(w) = ‖A w − b‖²-ish, written in the expression language
    let decls = vec![
        VarDecl::new("A", &[12, 5]),
        VarDecl::new("b", &[12]),
        VarDecl::new("w", &[5]),
    ];
    let mut g = Graph::new();
    let f = parse_expr(&mut g, &decls, "norm2(A*w-b) + 0.1*norm2(w)").unwrap();
    let w = g.var_id("w").unwrap();
    let (grad, hess) = grad_and_hessian(&mut g, f, w);
    let mut env = Env::new();
    env.insert("A", Tensor::randn(&[12, 5], 3));
    env.insert("b", Tensor::randn(&[12], 4));
    env.insert("w", Tensor::zeros(&[5]));
    // quadratic ⇒ one Newton step reaches the optimum
    let vals = eval_many(&g, &[grad, hess], &env);
    let step = solve_spd(&vals[1], &vals[0]).expect("SPD");
    env.insert("w", env.get("w").unwrap().sub(&step));
    let g_after = eval(&g, grad, &env);
    assert!(g_after.norm() < 1e-9, "‖grad‖ after Newton: {}", g_after.norm());
}

/// All three workloads: the four Hessian modes must agree numerically
/// and match finite differences of the gradient.
#[test]
fn workload_mode_consistency_matrix() {
    for mut w in [
        logistic_regression(10, 5),
        matrix_factorization(6, 6, 2, false),
        neural_net(4, 3, 6),
    ] {
        let name = w.name;
        let h = w.hessian();
        let hcc = w.hessian_cross_country();
        let comp = w.hessian_compressed();
        let pe = PerEntryHessian::new(&mut w.g, w.loss, w.wrt);

        let vals = eval_many(&w.g, &[h, hcc, comp.eval_node()], &w.env);
        let h_pe = pe.eval(&w.g, &w.env);
        assert!(vals[1].allclose(&vals[0], 1e-8, 1e-10), "{}: cc", name);
        let mat = comp.materialize(&vals[2]);
        assert!(mat.allclose(&vals[0], 1e-8, 1e-10), "{}: compressed", name);
        assert!(h_pe.allclose(&vals[0], 1e-8, 1e-10), "{}: per-entry", name);
    }
}

/// The matfac compressed-Newton path (the §3.3 claim) end-to-end.
#[test]
fn compressed_newton_drives_loss_to_conditional_optimum() {
    let mut w = matrix_factorization(12, 12, 3, false);
    let comp = w.hessian_compressed();
    assert!(comp.is_compressed());
    let grad_node = w.gradient();
    let core_node = comp.eval_node();
    let before = eval(&w.g, w.loss, &w.env).item();
    let vals = eval_many(&w.g, &[core_node, grad_node], &w.env);
    let step = newton_step_compressed(&vals[0], &vals[1]).unwrap();
    let u = w.env.get("U").unwrap().sub(&step);
    w.env.insert("U", u);
    let after = eval(&w.g, w.loss, &w.env).item();
    assert!(after < before, "loss must drop: {} -> {}", before, after);
    let g_after = eval(&w.g, grad_node, &w.env);
    assert!(g_after.norm() < 1e-8);
}

/// Gradients of all workloads validate against finite differences when
/// accessed through the public Workload API (not just internals).
#[test]
fn public_api_gradients_fd() {
    let mut w = logistic_regression(8, 4);
    let grad = w.gradient();
    let gv = eval(&w.g, grad, &w.env);
    let want = fd_gradient(&w.g, w.loss, "w", &w.env, 1e-6);
    assert!(gv.allclose(&want, 1e-5, 1e-7));
}

/// Coordinator serving an engine entry: many concurrent clients, all
/// responses correct (not just completed).
#[test]
fn coordinator_responses_are_correct() {
    let (m, n) = (12usize, 4usize);
    let mut w = logistic_regression(m, n);
    let grad = w.gradient();
    let mut c = Coordinator::new(64);
    c.register_engine(
        "grad",
        EngineEntry::compiled(
            &w.g,
            &[grad],
            vec![
                ("X".into(), vec![m, n]),
                ("y".into(), vec![m]),
                ("w".into(), vec![n]),
            ],
        ),
    );
    let mut handles = Vec::new();
    for seed in 0..16u64 {
        let x = Tensor::randn(&[m, n], seed);
        let y = Tensor::randn(&[m], seed + 50).map(f64::signum);
        let wv = Tensor::randn(&[n], seed + 100);
        let rx = c.submit("grad", vec![x.clone(), y.clone(), wv.clone()]).unwrap();
        handles.push((x, y, wv, rx));
    }
    for (x, y, wv, rx) in handles {
        let resp = rx.recv().unwrap().unwrap();
        // recompute directly
        let mut env = Env::new();
        env.insert("X", x);
        env.insert("y", y);
        env.insert("w", wv);
        let want = eval(&w.g, grad, &env);
        assert!(resp.outputs[0].allclose(&want, 1e-10, 1e-12));
    }
}

/// PJRT + engine agreement on the matfac Hessian core (artifact-gated).
#[test]
fn matfac_core_engine_vs_pjrt() {
    let Some(dir) = tensorcalc::runtime::artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut rt = tensorcalc::runtime::Runtime::open(&dir).unwrap();
    // AOT shape: V ∈ R^{128×5}
    let v = Tensor::randn(&[128, 5], 77);
    let out = rt.execute("matfac_hess_core", &[v.clone()]).unwrap();

    let mut w = matrix_factorization(128, 128, 5, false);
    w.env.insert("V", v);
    let comp = w.hessian_compressed();
    assert!(comp.is_compressed());
    let core = eval(&w.g, comp.eval_node(), &w.env);
    assert!(
        core.allclose(&out[0], 1e-3, 1e-3),
        "engine vs PJRT core diff {}",
        core.max_abs_diff(&out[0])
    );
}

/// The per-entry baseline costs Θ(n) reverse sweeps — verify the *count*,
/// which is what produces the Figure-3 gap.
#[test]
fn per_entry_sweep_count_scales() {
    let mut w = logistic_regression(8, 4);
    let pe = PerEntryHessian::new(&mut w.g, w.loss, w.wrt);
    assert_eq!(pe.sweeps(), 4);
    let mut w = matrix_factorization(6, 6, 3, false);
    let pe = PerEntryHessian::new(&mut w.g, w.loss, w.wrt);
    assert_eq!(pe.sweeps(), 18); // 6×3 matrix variable
}
