//! Differential pinning of the static memory planner
//! (`ExecMemory::Planned`, the default) against the PR 1 pooled
//! executor (`ExecMemory::Pooled`) and the interpreter:
//!
//! * Planned vs Pooled must be **bit-identical** (same instruction
//!   stream, same kernels, same accumulation order — only the buffers'
//!   addresses differ) across skinny, batched, permuted and Hessian
//!   workloads, fused and unfused;
//! * the planner's no-overlap invariant (no two live intervals share
//!   arena bytes) is re-checked on every plan the suite builds;
//! * steady state: after the warm-up run, `CompiledPlan::run` under
//!   `Planned` performs **zero** heap allocations (the `arena_allocs`
//!   counter freezes) and acquires **no** pool mutex (`pool_locks == 0`);
//! * concurrent runs of one shared plan are isolated (one arena per
//!   concurrent caller, results bit-stable).

use tensorcalc::eval::{Env, Plan};
use tensorcalc::exec::{BackendKind, CompiledPlan, EpilogueMode, ExecMemory};
use tensorcalc::ir::{Elem, Graph, NodeId};
use tensorcalc::obs::TraceMode;
use tensorcalc::opt::{optimize, OptLevel};
use tensorcalc::problems::{logistic_regression, matrix_factorization, neural_net};
use tensorcalc::tensor::Tensor;

/// Compile `(g, roots)` under both memory modes, pin them bit-identical
/// against each other and close against the interpreter, check the
/// memory plan's no-overlap invariant, and verify warm-arena re-runs are
/// bit-stable.
fn check_modes(g: &Graph, roots: &[NodeId], env: &Env, fuse: bool, label: &str) {
    let planned = CompiledPlan::with_options(
        g,
        roots,
        fuse,
        EpilogueMode::default(),
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    planned.validate_memory_plan();
    let pooled = CompiledPlan::with_options(
        g,
        roots,
        fuse,
        EpilogueMode::default(),
        ExecMemory::Pooled,
        BackendKind::default(),
        TraceMode::Off,
    );
    let a = planned.run(env);
    let b = pooled.run(env);
    let want = Plan::new(g, roots).run(g, env);
    assert_eq!(a.len(), b.len());
    for (k, ((ta, tb), tw)) in a.iter().zip(&b).zip(&want).enumerate() {
        assert_eq!(
            ta.data(),
            tb.data(),
            "{label}: root {k}: Planned vs Pooled must be bit-identical"
        );
        assert!(
            ta.allclose(tw, 1e-9, 1e-11),
            "{label}: root {k}: vs interpreter diff {}",
            ta.max_abs_diff(tw)
        );
    }
    // the warm arena must not leak state between runs
    let again = planned.run(env);
    for (k, (x, y)) in a.iter().zip(&again).enumerate() {
        assert_eq!(x.data(), y.data(), "{label}: root {k}: warm re-run drifted");
    }
}

#[test]
fn skinny_gradient_workload() {
    // tall-thin logreg: skinny GEMMs, scalar loss + vector gradient roots
    let mut w = logistic_regression(96, 8);
    let grad = w.gradient();
    check_modes(&w.g, &[w.loss, grad], &w.env, true, "logreg-grad fused");
    check_modes(&w.g, &[w.loss, grad], &w.env, false, "logreg-grad unfused");
}

#[test]
fn batched_contraction_workload() {
    // 400 small batch slices cross the parallel-batch gate (400·6³ >
    // PAR_BATCH_TOTAL_MIN_FLOP); a fused chain rides on the contraction
    let (bsz, d) = (400usize, 6usize);
    let mut g = Graph::new();
    let a = g.var("A", &[bsz, d, d]);
    let b = g.var("B", &[bsz, d, d]);
    let ab = g.mul(a, b, tensorcalc::einsum::EinSpec::parse("aij,ajk->aik"));
    let t = g.elem(Elem::Tanh, ab);
    let y = g.scale(t, 0.5);
    let mut env = Env::new();
    env.insert("A", Tensor::randn(&[bsz, d, d], 41));
    env.insert("B", Tensor::randn(&[bsz, d, d], 42));
    check_modes(&g, &[y], &env, true, "batched fused");
    check_modes(&g, &[y], &env, false, "batched unfused");
}

#[test]
fn permuted_output_workload() {
    // "ij,jk->ki" exercises the gather + permute path, whose scratch
    // regions (a/b staging and the pre-permutation product) live in the
    // arena under Planned
    let (m, k, n) = (33usize, 47, 29);
    let mut g = Graph::new();
    let a = g.var("A", &[m, k]);
    let b = g.var("B", &[k, n]);
    let ab = g.mul(a, b, tensorcalc::einsum::EinSpec::parse("ij,jk->ki"));
    let t = g.elem(Elem::Tanh, ab);
    let tt = g.transpose(t, &[1, 0]);
    let y = g.matmul(tt, a);
    let mut env = Env::new();
    env.insert("A", Tensor::randn(&[m, k], 51));
    env.insert("B", Tensor::randn(&[k, n], 52));
    check_modes(&g, &[y], &env, true, "permuted fused");
    check_modes(&g, &[y], &env, false, "permuted unfused");
}

#[test]
fn hessian_workloads() {
    // whole optimized Hessian DAGs — deep levels, shared sub-DAGs, the
    // planner's worst case for interval packing
    for (name, mut w) in [
        ("logreg", logistic_regression(24, 6)),
        ("matfac", matrix_factorization(10, 10, 3, false)),
        ("mlp", neural_net(6, 4, 10)),
    ] {
        let h = w.hessian();
        let mut g2 = w.g.clone();
        let o = optimize(&mut g2, &[h], OptLevel::Full);
        check_modes(&g2, &o.roots, &w.env, true, name);
    }
}

#[test]
fn epilogue_modes_bit_identical_under_planned() {
    // TwoPass vs InTile must stay bit-identical when both run on arena
    // offsets
    let (m, k, n) = (65usize, 257, 130);
    let mut g = Graph::new();
    let x = g.var("X", &[m, k]);
    let w = g.var("W", &[k, n]);
    let xw = g.matmul(x, w);
    let t = g.elem(Elem::Tanh, xw);
    let y = g.hadamard(t, xw);
    let mut env = Env::new();
    env.insert("X", Tensor::randn(&[m, k], 61));
    env.insert("W", Tensor::randn(&[k, n], 62));
    let in_tile = CompiledPlan::with_options(
        &g,
        &[y],
        true,
        EpilogueMode::InTile,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    let two_pass = CompiledPlan::with_options(
        &g,
        &[y],
        true,
        EpilogueMode::TwoPass,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    assert!(in_tile.fused_count() >= 1);
    let a = in_tile.run(&env);
    let b = two_pass.run(&env);
    assert_eq!(a[0].data(), b[0].data());
}

#[test]
fn steady_state_allocates_nothing_and_takes_no_pool_lock() {
    let mut w = logistic_regression(64, 16);
    let grad = w.gradient();
    let plan = CompiledPlan::new(&w.g, &[w.loss, grad]); // Planned default
    assert_eq!(plan.memory(), ExecMemory::Planned);
    let first = plan.run(&w.env);
    let cold = plan.pool_stats();
    assert!(cold.arena_bytes > 0, "the gradient DAG has intermediates to plan");
    assert_eq!(cold.arena_allocs, 1, "first run grows exactly one arena");
    let runs = 20;
    for _ in 0..runs {
        let again = plan.run(&w.env);
        assert_eq!(again[0].data(), first[0].data());
        assert_eq!(again[1].data(), first[1].data());
    }
    let warm = plan.pool_stats();
    // the acceptance criterion: steady-state runs perform zero heap
    // allocation (the arena never grows again) and never touch the
    // buffer-pool mutex
    assert_eq!(
        warm.arena_allocs, cold.arena_allocs,
        "a steady-state run allocated: {:?}",
        warm
    );
    assert_eq!(warm.pool_locks, 0, "planned mode acquired the pool mutex: {:?}", warm);
    assert_eq!(warm.fresh, 0);
    assert_eq!(warm.reused, 0);
}

#[test]
fn pooled_mode_still_counts_its_locks() {
    // sanity for the counter the planned assertion relies on: the
    // pooled ablation *does* take the mutex
    let mut w = logistic_regression(16, 4);
    let grad = w.gradient();
    let plan = CompiledPlan::with_options(
        &w.g,
        &[w.loss, grad],
        true,
        EpilogueMode::default(),
        ExecMemory::Pooled,
        BackendKind::default(),
        TraceMode::Off,
    );
    let _ = plan.run(&w.env);
    let st = plan.pool_stats();
    assert!(st.pool_locks > 0, "pooled mode must go through lock_pool: {:?}", st);
    assert!(st.fresh > 0);
    assert_eq!(st.arena_bytes, 0);
}

#[test]
fn packing_reuses_dead_bytes_and_chains_in_place() {
    // unfused Elem chain: every link dies as the next is written, so the
    // whole chain must collapse onto ONE arena slot via in-place
    // transfers
    let len = 64usize;
    let mut g = Graph::new();
    let x = g.var("x", &[len]);
    let mut v = g.elem(Elem::Tanh, x);
    for _ in 0..5 {
        v = g.elem(Elem::Sigmoid, v);
    }
    let mut env = Env::new();
    env.insert("x", Tensor::randn(&[len], 7));
    let planned = CompiledPlan::with_options(
        &g,
        &[v],
        false,
        EpilogueMode::default(),
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    planned.validate_memory_plan();
    let st = planned.pool_stats();
    assert_eq!(
        st.arena_bytes,
        (len * std::mem::size_of::<f64>()) as u64,
        "the whole unfused chain must fit in one slot: {:?}",
        st
    );
    assert_eq!(st.inplace_reuse, 5, "every link must take over its input in place");
    // and in-place execution must not change the numerics
    let pooled = CompiledPlan::with_options(
        &g,
        &[v],
        false,
        EpilogueMode::default(),
        ExecMemory::Pooled,
        BackendKind::default(),
        TraceMode::Off,
    );
    let a = planned.run(&env);
    let b = pooled.run(&env);
    assert_eq!(a[0].data(), b[0].data());

    // a diamond (two same-shape branches live at once) must pack the
    // second branch into recycled bytes once the first dies
    let mut g2 = Graph::new();
    let x2 = g2.var("x", &[256]);
    let t1 = g2.elem(Elem::Tanh, x2);
    let s1 = g2.elem(Elem::Sigmoid, x2);
    let d = g2.hadamard(t1, s1);
    let e = g2.elem(Elem::Exp, d);
    let y2 = g2.hadamard(e, e);
    let mut env2 = Env::new();
    env2.insert("x", Tensor::randn(&[256], 8));
    let p2 = CompiledPlan::with_options(
        &g2,
        &[y2],
        false,
        EpilogueMode::default(),
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    p2.validate_memory_plan();
    let st2 = p2.pool_stats();
    assert!(
        st2.planned_reuse + st2.inplace_reuse > 0,
        "the diamond must reuse freed bytes: {:?}",
        st2
    );
    check_modes(&g2, &[y2], &env2, false, "diamond unfused");
}

#[test]
fn wide_parallel_level_is_planned_disjoint() {
    // one wide level above the fork gate: concurrent instructions write
    // planner-assigned disjoint slots on the persistent worker pool
    let mut g = Graph::new();
    let x = g.var("x", &[4096]);
    let roots: Vec<NodeId> = (0..64).map(|i| g.scale(x, 1.0 + i as f64 * 0.01)).collect();
    let mut env = Env::new();
    env.insert("x", Tensor::randn(&[4096], 11));
    let plan = CompiledPlan::new(&g, &roots);
    plan.validate_memory_plan();
    let got = plan.run(&env);
    let want = Plan::new(&g, &roots).run(&g, &env);
    for (i, (gt, wt)) in got.iter().zip(&want).enumerate() {
        assert!(
            gt.allclose(wt, 1e-12, 1e-14),
            "root {}: parallel planned level diverged, diff {}",
            i,
            gt.max_abs_diff(wt)
        );
    }
}

#[test]
fn concurrent_planned_runs_are_isolated() {
    let mut w = logistic_regression(32, 8);
    let grad = w.gradient();
    let plan = CompiledPlan::new(&w.g, &[w.loss, grad]);
    let want = plan.run(&w.env);
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                for _ in 0..10 {
                    let got = plan.run(&w.env);
                    assert_eq!(got[0].data(), want[0].data(), "concurrent run diverged");
                    assert_eq!(got[1].data(), want[1].data());
                }
            });
        }
    });
    let st = plan.pool_stats();
    assert!(
        st.arena_allocs <= 5,
        "at most one arena per concurrent caller: {:?}",
        st
    );
    assert_eq!(st.pool_locks, 0);
}

#[test]
fn planned_rejects_bad_bindings_like_the_interpreter() {
    let mut g = Graph::new();
    let x = g.var("x", &[3]);
    let y = g.elem(Elem::Exp, x);
    let plan = CompiledPlan::new(&g, &[y]);
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[4], 1)); // wrong shape
        plan.run(&env)
    }));
    assert!(err.is_err(), "wrong-shape binding must panic under Planned too");
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| plan.run(&Env::new())));
    assert!(err.is_err(), "unbound variable must panic under Planned too");
}
