//! Property-based tests (in-crate generator — the offline build has no
//! proptest): randomized einsum specs against a brute-force oracle,
//! semantics preservation under simplify/cross-country, mode agreement
//! on random DAGs, and FD validation of random derivative chains.

use std::sync::Mutex;

use tensorcalc::einsum::{einsum, gemm_into, gemm_into_epi, gemm_into_flat, EpiFn};
use tensorcalc::einsum::{EinSpec, Label};
use tensorcalc::eval::{eval, eval_many, fd_gradient, Env};
use tensorcalc::ir::{Elem, Graph, NodeId};
use tensorcalc::prelude::*;
use tensorcalc::tensor::{Tensor, XorShift};
use tensorcalc::util::simd::{blocking, set_isa, supported_isas, Isa};

/// Brute-force einsum reference (independent of the engine's fast paths).
fn einsum_naive(spec: &EinSpec, a: &Tensor, b: &Tensor) -> Tensor {
    let out_shape = spec.output_shape(a.shape(), b.shape()).unwrap();
    let mut labels: Vec<Label> = Vec::new();
    let mut dims: Vec<usize> = Vec::new();
    for (&l, &d) in spec.s1.iter().zip(a.shape()).chain(spec.s2.iter().zip(b.shape())) {
        if !labels.contains(&l) {
            labels.push(l);
            dims.push(d);
        }
    }
    let total: usize = dims.iter().product::<usize>().max(1);
    let mut out = Tensor::zeros(&out_shape);
    let pos = |l: Label| labels.iter().position(|&x| x == l).unwrap();
    for flat in 0..total {
        let mut assign = vec![0usize; labels.len()];
        let mut rem = flat;
        for i in (0..labels.len()).rev() {
            assign[i] = rem % dims[i];
            rem /= dims[i];
        }
        let ai: Vec<usize> = spec.s1.iter().map(|&l| assign[pos(l)]).collect();
        let bi: Vec<usize> = spec.s2.iter().map(|&l| assign[pos(l)]).collect();
        let oi: Vec<usize> = spec.s3.iter().map(|&l| assign[pos(l)]).collect();
        let mut oflat = 0usize;
        for (x, &d) in oi.iter().zip(&out_shape) {
            oflat = oflat * d + x;
        }
        out.data_mut()[oflat] += a.at(&ai) * b.at(&bi);
    }
    out
}

/// Generate a random valid spec + matching operand shapes.
fn random_spec(rng: &mut XorShift) -> (EinSpec, Vec<usize>, Vec<usize>) {
    let n_labels = 1 + rng.below(4); // 1..4 distinct labels
    let dims: Vec<usize> = (0..n_labels).map(|_| 1 + rng.below(4)).collect();
    let ra = 1 + rng.below(3);
    let rb = rng.below(3);
    let s1: Vec<Label> = (0..ra).map(|_| rng.below(n_labels) as Label).collect();
    let s2: Vec<Label> = (0..rb).map(|_| rng.below(n_labels) as Label).collect();
    // output: random subset of distinct used labels
    let mut used: Vec<Label> = Vec::new();
    for &l in s1.iter().chain(&s2) {
        if !used.contains(&l) {
            used.push(l);
        }
    }
    let mut s3 = Vec::new();
    for &l in &used {
        if rng.below(2) == 0 {
            s3.push(l);
        }
    }
    // random permutation of s3
    for i in (1..s3.len()).rev() {
        let j = rng.below(i + 1);
        s3.swap(i, j);
    }
    let a_shape: Vec<usize> = s1.iter().map(|&l| dims[l as usize]).collect();
    let b_shape: Vec<usize> = s2.iter().map(|&l| dims[l as usize]).collect();
    (EinSpec::new(s1, s2, s3), a_shape, b_shape)
}

#[test]
fn prop_einsum_matches_bruteforce_on_200_random_specs() {
    let mut rng = XorShift::new(2024);
    for case in 0..200 {
        let (spec, sa, sb) = random_spec(&mut rng);
        let a = Tensor::randn(&sa, 1000 + case);
        let b = Tensor::randn(&sb, 2000 + case);
        let fast = einsum(&spec, &a, &b);
        let slow = einsum_naive(&spec, &a, &b);
        assert!(
            fast.allclose(&slow, 1e-9, 1e-9),
            "case {}: {} on {:?}×{:?}, diff {}",
            case,
            spec,
            sa,
            sb,
            fast.max_abs_diff(&slow)
        );
    }
}

#[test]
fn prop_einsum_commutativity() {
    // Lemma 2: A *_(s1,s2,s3) B == B *_(s2,s1,s3) A
    let mut rng = XorShift::new(7);
    for case in 0..100 {
        let (spec, sa, sb) = random_spec(&mut rng);
        let a = Tensor::randn(&sa, 3000 + case);
        let b = Tensor::randn(&sb, 4000 + case);
        let lhs = einsum(&spec, &a, &b);
        let rhs = einsum(&spec.swapped(), &b, &a);
        assert!(lhs.allclose(&rhs, 1e-10, 1e-11), "case {}: {}", case, spec);
    }
}

#[test]
fn prop_einsum_distributivity() {
    // Lemma 3: A*(B+C) == A*B + A*C (same spec)
    let mut rng = XorShift::new(9);
    for case in 0..100 {
        let (spec, sa, sb) = random_spec(&mut rng);
        let a = Tensor::randn(&sa, 5000 + case);
        let b = Tensor::randn(&sb, 6000 + case);
        let c = Tensor::randn(&sb, 7000 + case);
        let lhs = einsum(&spec, &a, &b.add(&c));
        let rhs = einsum(&spec, &a, &b).add(&einsum(&spec, &a, &c));
        assert!(lhs.allclose(&rhs, 1e-9, 1e-10), "case {}: {}", case, spec);
    }
}

/// Random expression DAG over a small pool of variables.
struct DagGen {
    rng: XorShift,
}

impl DagGen {
    /// Build a random scalar expression of `x` (shape [n]) and `a`
    /// (shape [n, n]) using smooth, domain-safe ops.
    fn random_scalar_expr(&mut self, g: &mut Graph, depth: usize) -> NodeId {
        let x = g.var("x", &[4]);
        let a = g.var("A", &[4, 4]);
        let mut v = g.matvec(a, x); // [4]
        for _ in 0..depth {
            v = match self.rng.below(6) {
                0 => g.elem(Elem::Tanh, v),
                1 => g.elem(Elem::Sigmoid, v),
                2 => {
                    let e = g.elem(Elem::Exp, v);
                    let half = g.scale(e, 0.2);
                    g.elem(Elem::Tanh, half)
                }
                3 => g.hadamard(v, x),
                4 => {
                    let av = g.matvec(a, v);
                    g.scale(av, 0.5)
                }
                _ => {
                    let t = g.tmatvec(a, v);
                    g.add(t, x)
                }
            };
        }
        let sq = g.elem(Elem::Square, v);
        g.sum_all(sq)
    }
}

#[test]
fn prop_simplify_and_cc_preserve_random_gradients() {
    for seed in 0..25u64 {
        let mut gen = DagGen { rng: XorShift::new(seed) };
        let mut g = Graph::new();
        let depth = 1 + (seed % 4) as usize;
        let f = gen.random_scalar_expr(&mut g, depth);
        let x = g.var_id("x").unwrap();
        let raw = reverse_derivative(&mut g, f, &[x])[0];
        let simpl = simplify(&mut g, &[raw])[0];
        let cc = optimize_contractions(&mut g, simpl);
        let cc = simplify(&mut g, &[cc])[0];
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[4], seed + 1).scale(0.5));
        env.insert("A", Tensor::randn(&[4, 4], seed + 2).scale(0.5));
        let vals = eval_many(&g, &[raw, simpl, cc], &env);
        assert!(
            vals[1].allclose(&vals[0], 1e-8, 1e-10),
            "seed {}: simplify changed value, diff {}",
            seed,
            vals[1].max_abs_diff(&vals[0])
        );
        assert!(
            vals[2].allclose(&vals[0], 1e-8, 1e-10),
            "seed {}: cross-country changed value, diff {}",
            seed,
            vals[2].max_abs_diff(&vals[0])
        );
    }
}

#[test]
fn prop_forward_equals_reverse_on_random_dags() {
    for seed in 100..115u64 {
        let mut gen = DagGen { rng: XorShift::new(seed) };
        let mut g = Graph::new();
        let f = gen.random_scalar_expr(&mut g, 2);
        let x = g.var_id("x").unwrap();
        let r = reverse_derivative(&mut g, f, &[x])[0];
        let fw = forward_derivative(&mut g, f, x);
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[4], seed + 1).scale(0.5));
        env.insert("A", Tensor::randn(&[4, 4], seed + 2).scale(0.5));
        let vals = eval_many(&g, &[r, fw], &env);
        assert!(
            vals[0].allclose(&vals[1], 1e-9, 1e-11),
            "seed {}: modes disagree, diff {}",
            seed,
            vals[0].max_abs_diff(&vals[1])
        );
    }
}

#[test]
fn prop_gradients_match_fd_on_random_dags() {
    for seed in 200..212u64 {
        let mut gen = DagGen { rng: XorShift::new(seed) };
        let mut g = Graph::new();
        let f = gen.random_scalar_expr(&mut g, 2);
        let x = g.var_id("x").unwrap();
        let grad = reverse_derivative(&mut g, f, &[x])[0];
        let grad = simplify(&mut g, &[grad])[0];
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[4], seed + 1).scale(0.4));
        env.insert("A", Tensor::randn(&[4, 4], seed + 2).scale(0.4));
        let gv = eval(&g, grad, &env);
        let want = fd_gradient(&g, f, "x", &env, 1e-6);
        assert!(
            gv.allclose(&want, 1e-4, 1e-6),
            "seed {}: FD mismatch, diff {}",
            seed,
            gv.max_abs_diff(&want)
        );
    }
}

#[test]
fn prop_hessian_symmetry_on_random_dags() {
    use tensorcalc::autodiff::hessian::hessian;
    for seed in 300..308u64 {
        let mut gen = DagGen { rng: XorShift::new(seed) };
        let mut g = Graph::new();
        let f = gen.random_scalar_expr(&mut g, 2);
        let x = g.var_id("x").unwrap();
        let h = hessian(&mut g, f, x);
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[4], seed + 1).scale(0.4));
        env.insert("A", Tensor::randn(&[4, 4], seed + 2).scale(0.4));
        let hv = eval(&g, h, &env);
        assert!(
            hv.allclose(&hv.t(), 1e-8, 1e-10),
            "seed {}: Hessian asymmetric, diff {}",
            seed,
            hv.max_abs_diff(&hv.t())
        );
    }
}

// ---------------------------------------------------------------------------
// GEMM shape fuzzer: the dispatched tiled kernel against its references
// ---------------------------------------------------------------------------
//
// Four implementations of `C += A·B` are pinned **bit-identical** (not
// allclose) on random awkward shapes: the tiled kernel under every
// dispatched ISA, the tiled kernel forced scalar, `gemm_into_flat`, and
// an in-file naive triple loop. This works because all four accumulate
// each `C[i][j]` along `k` in increasing order with separate mul/add,
// and the tiled path flushes its register tile to `C` exactly once when
// `k ≤ KC` — so the fuzzer draws `k ≤ blocking().kc` for the four-way
// pin and larger `k` (multi-flush) for the SIMD-vs-scalar-only pin.
//
// The ISA is process-global, so the tests that flip it serialize.

static GEMM_ISA_LOCK: Mutex<()> = Mutex::new(());

/// Flip the active ISA, restoring the previous tier on drop.
struct IsaFlip {
    prev: Isa,
}

impl IsaFlip {
    fn to(isa: Isa) -> IsaFlip {
        IsaFlip { prev: set_isa(isa) }
    }
}

impl Drop for IsaFlip {
    fn drop(&mut self) {
        set_isa(self.prev);
    }
}

fn matmul_naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
    c
}

/// A dimension biased toward the edges the tiling must get right: 1,
/// one under/over the register tile, exact tile multiples, and noise.
fn awkward_dim(rng: &mut XorShift, tile: usize) -> usize {
    match rng.below(6) {
        0 => 1,
        1 => tile - 1,
        2 => tile + 1,
        3 => tile * (1 + rng.below(8)),
        _ => 1 + rng.below(97),
    }
}

fn rand_mat(rng: &mut XorShift, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.next_f64() - 0.5).collect()
}

#[test]
fn prop_gemm_fuzz_four_way_bit_identity() {
    let _lock = GEMM_ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let isas = supported_isas();
    let blk = blocking();
    let mut rng = XorShift::new(0xF002);
    let mut tiled_hits = 0usize;
    for case in 0..60usize {
        let (m, n, k);
        if case < 5 {
            // guaranteed deep into the tiled path: both dims past the
            // register tile and well over the min-flop gate
            m = blk.mr * 3 + case;
            n = blk.nr * 5 + 1;
            k = blk.kc.min(64 + 7 * case);
        } else {
            m = awkward_dim(&mut rng, blk.mr);
            n = awkward_dim(&mut rng, blk.nr);
            k = 1 + rng.below(blk.kc); // single register flush: k ≤ KC
        }
        if m >= blk.mr && n >= blk.nr && m * n * k >= 1 << 14 {
            tiled_hits += 1;
        }
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let want = matmul_naive(&a, &b, m, k, n);
        let mut flat = vec![0.0; m * n];
        gemm_into_flat(&a, &b, &mut flat, m, k, n);
        assert_eq!(flat, want, "case {case} ({m}x{k}x{n}): flat != naive");
        for &isa in &isas {
            let _s = IsaFlip::to(isa);
            let mut c = vec![0.0; m * n];
            gemm_into(&a, &b, &mut c, m, k, n);
            assert_eq!(
                c,
                want,
                "case {case} ({m}x{k}x{n}): tiled under {} != naive",
                isa.name()
            );
        }
    }
    // the generator must actually exercise the tiled path, not just
    // fall through to the flat small-shape gate every time
    assert!(tiled_hits >= 8, "only {tiled_hits}/60 cases engaged the tiled path");
}

#[test]
fn prop_gemm_fuzz_epilogue_fused_and_accumulating() {
    let _lock = GEMM_ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let isas = supported_isas();
    let blk = blocking();
    let mut rng = XorShift::new(0xF003);
    // the affine test epilogue sees *global* offsets (c_base included)
    let epi = |base: usize, seg: &mut [f64]| {
        for (i, v) in seg.iter_mut().enumerate() {
            *v = 2.0 * *v + (base + i) as f64 * 0.001;
        }
    };
    for case in 0..40 {
        let m = awkward_dim(&mut rng, blk.mr);
        let n = awkward_dim(&mut rng, blk.nr);
        // multi-KC-block k on odd cases: the epilogue must still fire
        // exactly once per element, on the *last* flush only
        let k = if case % 2 == 0 { 1 + rng.below(blk.kc) } else { blk.kc + 1 + rng.below(64) };
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let c_base = rng.below(1000);

        // reference: plain accumulate, then one sweep at the same
        // global offsets — only valid bitwise when k ≤ KC
        let scalar_fused = {
            let _s = IsaFlip::to(Isa::Scalar);
            let mut c = vec![0.0; m * n];
            gemm_into_epi(&a, &b, &mut c, m, k, n, c_base, &EpiFn(epi));
            c
        };
        if k <= blk.kc {
            let mut want = matmul_naive(&a, &b, m, k, n);
            epi(c_base, &mut want);
            assert_eq!(scalar_fused, want, "case {case} ({m}x{k}x{n}): fused != gemm-then-sweep");
        }
        // every dispatched ISA reproduces the fused scalar result bit
        // for bit, multi-flush shapes included
        for &isa in &isas[1..] {
            let _s = IsaFlip::to(isa);
            let mut c = vec![0.0; m * n];
            gemm_into_epi(&a, &b, &mut c, m, k, n, c_base, &EpiFn(epi));
            assert_eq!(
                c,
                scalar_fused,
                "case {case} ({m}x{k}x{n}): fused under {} != scalar",
                isa.name()
            );
        }

        // accumulating into a pre-filled C (the `+=` contract): scalar
        // vs SIMD share the path, so this needs no k cap either
        let prefill: Vec<f64> = (0..m * n).map(|i| (i % 9) as f64 * 0.25 - 1.0).collect();
        let scalar_acc = {
            let _s = IsaFlip::to(Isa::Scalar);
            let mut c = prefill.clone();
            gemm_into(&a, &b, &mut c, m, k, n);
            c
        };
        for &isa in &isas[1..] {
            let _s = IsaFlip::to(isa);
            let mut c = prefill.clone();
            gemm_into(&a, &b, &mut c, m, k, n);
            assert_eq!(
                c,
                scalar_acc,
                "case {case} ({m}x{k}x{n}): accumulate under {} != scalar",
                isa.name()
            );
        }
    }
}

#[test]
fn prop_einsum_batched_permuted_bit_identical_across_isas() {
    // above the kernel seam: batched and output-permuted einsum specs
    // route through `batched_gemm_epi` / packed panels with per-slice
    // `c_base` offsets — the dispatched kernels must stay bit-identical
    // to forced scalar through all of that plumbing, and allclose to
    // the brute-force oracle
    let _lock = GEMM_ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let isas = supported_isas();
    let specs =
        ["ij,jk->ik", "ij,jk->ki", "bij,bjk->bik", "bij,bjk->ikb", "ij,kj->ik", "bi,bij->bj"];
    let mut rng = XorShift::new(0xF004);
    for case in 0..30usize {
        let spec = EinSpec::parse(specs[case % specs.len()]);
        let mut dims = std::collections::HashMap::new();
        let mut shape_of = |labels: &[Label], rng: &mut XorShift| -> Vec<usize> {
            labels
                .iter()
                .map(|&l| *dims.entry(l).or_insert_with(|| 1 + rng.below(13)))
                .collect()
        };
        let sa = shape_of(&spec.s1, &mut rng);
        let sb = shape_of(&spec.s2, &mut rng);
        let a = Tensor::randn(&sa, 9100 + case as u64);
        let b = Tensor::randn(&sb, 9200 + case as u64);
        let base = {
            let _s = IsaFlip::to(Isa::Scalar);
            einsum(&spec, &a, &b)
        };
        let slow = einsum_naive(&spec, &a, &b);
        assert!(
            base.allclose(&slow, 1e-9, 1e-10),
            "case {case}: {spec} on {sa:?}x{sb:?}, diff {}",
            base.max_abs_diff(&slow)
        );
        for &isa in &isas[1..] {
            let _s = IsaFlip::to(isa);
            let fast = einsum(&spec, &a, &b);
            assert_eq!(
                fast.data(),
                base.data(),
                "case {case}: {spec} under {} != scalar",
                isa.name()
            );
        }
    }
}

#[test]
fn prop_reduce_then_expand_roundtrips() {
    // Σ over fresh outer-product axis recovers a scale: Σ_j (x ⊗ 1_j) = m·x
    let mut rng = XorShift::new(11);
    for _ in 0..50 {
        let n = 1 + rng.below(6);
        let m = 1 + rng.below(6);
        let x = Tensor::randn(&[n], rng.next_u64());
        let ones = Tensor::ones(&[m]);
        let outer = einsum(&EinSpec::parse("i,j->ij"), &x, &ones);
        let back = einsum(&EinSpec::parse("ij,->i"), &outer, &Tensor::scalar(1.0));
        assert!(back.allclose(&x.scale(m as f64), 1e-10, 1e-11));
    }
}
