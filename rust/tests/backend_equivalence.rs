//! Differential pinning of the execution backends behind the
//! `exec::backend` seam: `BackendKind::Cpu` (the level-parallel
//! work-stealing executor) vs `BackendKind::Direct` (the
//! direct-threaded closure chain) vs the interpreter oracle.
//!
//! The backend contract under test:
//!
//! * both backends consume the **same** backend-neutral `Lowered`
//!   artifact (same instruction stream, same fused kernels, same
//!   accumulation order), so their outputs must be **bit-identical** —
//!   not merely close — across every workload, memory discipline and
//!   epilogue mode;
//! * both must stay allclose to the un-fused interpreter
//!   ([`tensorcalc::eval::Plan`]), the reference semantics;
//! * the direct backend always executes in-arena (it forces a memory
//!   plan even under the `Pooled` ablation mode), so its steady state
//!   takes no pool lock and its plan passes the no-overlap check;
//! * warm re-runs are bit-stable on both sides.

use tensorcalc::eval::{Env, Plan};
use tensorcalc::exec::{batch_graph, BackendKind, CompiledPlan, EpilogueMode, ExecMemory};
use tensorcalc::ir::{Graph, NodeId};
use tensorcalc::obs::TraceMode;
use tensorcalc::opt::{compact, optimize, OptLevel};
use tensorcalc::problems::{logistic_regression, matrix_factorization, neural_net};
use tensorcalc::tensor::Tensor;

/// Compile `(g, roots)` for both backends under the given options, pin
/// them bit-identical against each other and close against the
/// interpreter, re-check the no-overlap invariant, and verify warm
/// re-runs are bit-stable.
fn check_backends(
    g: &Graph,
    roots: &[NodeId],
    env: &Env,
    memory: ExecMemory,
    epilogue: EpilogueMode,
    label: &str,
) {
    let cpu = CompiledPlan::with_options(
        g,
        roots,
        true,
        epilogue,
        memory,
        BackendKind::Cpu,
        TraceMode::Off,
    );
    let direct = CompiledPlan::with_options(
        g,
        roots,
        true,
        epilogue,
        memory,
        BackendKind::Direct,
        TraceMode::Off,
    );
    assert_eq!(cpu.backend(), BackendKind::Cpu);
    assert_eq!(direct.backend(), BackendKind::Direct);
    // both artifacts lower from the same stream — the direct backend
    // must not change what was compiled, only how it executes
    assert_eq!(cpu.len(), direct.len(), "{label}: lowering diverged across backends");
    assert_eq!(cpu.fused_count(), direct.fused_count());
    cpu.validate_memory_plan();
    direct.validate_memory_plan();

    let a = cpu.run(env);
    let b = direct.run(env);
    let want = Plan::new(g, roots).run(g, env);
    assert_eq!(a.len(), b.len());
    for (k, ((ta, tb), tw)) in a.iter().zip(&b).zip(&want).enumerate() {
        assert_eq!(
            ta.data(),
            tb.data(),
            "{label}: root {k}: cpu vs direct must be bit-identical"
        );
        assert!(
            ta.allclose(tw, 1e-9, 1e-11),
            "{label}: root {k}: vs interpreter diff {}",
            ta.max_abs_diff(tw)
        );
    }
    // warm re-runs must not drift on either side
    let a2 = cpu.run(env);
    let b2 = direct.run(env);
    for (k, ((x, y), (x2, y2))) in a.iter().zip(&b).zip(a2.iter().zip(&b2)).enumerate() {
        assert_eq!(x.data(), x2.data(), "{label}: root {k}: cpu warm re-run drifted");
        assert_eq!(y.data(), y2.data(), "{label}: root {k}: direct warm re-run drifted");
    }
}

/// Every (memory, epilogue) cell of the option matrix for one workload.
fn check_matrix(g: &Graph, roots: &[NodeId], env: &Env, label: &str) {
    for memory in [ExecMemory::Planned, ExecMemory::Pooled] {
        for epilogue in [EpilogueMode::InTile, EpilogueMode::TwoPass] {
            check_backends(
                g,
                roots,
                env,
                memory,
                epilogue,
                &format!("{label} [{:?}/{:?}]", memory, epilogue),
            );
        }
    }
}

#[test]
fn logreg_gradient_across_backends() {
    let mut w = logistic_regression(96, 8);
    let grad = w.gradient();
    check_matrix(&w.g, &[w.loss, grad], &w.env, "logreg-grad");
}

#[test]
fn matfac_compressed_hessian_across_backends() {
    // the §3.3 compressed Hessian core (k×k instead of the order-4
    // tensor): dense contraction chains over shared sub-DAGs
    let mut w = matrix_factorization(12, 12, 3, false);
    let comp = w.hessian_compressed();
    assert!(comp.is_compressed());
    let core = comp.eval_node();
    check_matrix(&w.g, &[core], &w.env, "matfac-hess-compressed");
}

#[test]
fn neural_net_hessian_across_backends() {
    // reverse-over-reverse MLP Hessian, optimized: deep levels and the
    // widest fan-out the suite has — the strongest contrast between the
    // work-stealing schedule and the sequential closure chain
    let mut w = neural_net(6, 4, 10);
    let h = w.hessian();
    let mut g2 = w.g.clone();
    let o = optimize(&mut g2, &[h], OptLevel::Full);
    check_matrix(&g2, &o.roots, &w.env, "mlp-hess");
}

#[test]
fn batched_serving_variant_across_backends() {
    // the serving path's shape: canonicalise exactly as
    // `EngineEntry::compiled` does, derive the batched variant, and pin
    // both backends on it slice by slice against the sequential base
    // plan on the *original* graph's interpreter
    let bsz = 4usize;
    let mut w = logistic_regression(8, 4);
    let grad = w.gradient();
    let roots = [w.loss, grad];
    let mut g2 = w.g.clone();
    let o = optimize(&mut g2, &roots, OptLevel::Full);
    let (gc, croots) = compact(&g2, &o.roots);
    let (bg, broots) = batch_graph(&gc, &croots, bsz);

    let vars: Vec<(String, Vec<usize>)> = w
        .g
        .var_names()
        .into_iter()
        .map(|n| {
            let id = w.g.var_id(&n).unwrap();
            (n, w.g.shape(id).to_vec())
        })
        .collect();
    let mut envs = Vec::new();
    for b in 0..bsz {
        let mut env = Env::new();
        for (i, (name, shape)) in vars.iter().enumerate() {
            let seed = 700 + (b * vars.len() + i) as u64;
            env.insert(name, Tensor::randn(shape, seed).scale(0.5));
        }
        envs.push(env);
    }
    let mut benv = Env::new();
    for (name, _) in &vars {
        let mut bshape = vec![bsz];
        let first = envs[0].get(name).unwrap();
        bshape.extend_from_slice(first.shape());
        let mut data = Vec::with_capacity(bsz * first.len());
        for e in &envs {
            data.extend_from_slice(e.get(name).unwrap().data());
        }
        benv.insert(name, Tensor::new(&bshape, data));
    }

    check_matrix(&bg, &broots, &benv, "logreg-grad-batched");

    // and the batched outputs decompose into the per-request answers
    let bplan = CompiledPlan::with_backend(&bg, &broots, BackendKind::Direct);
    let batched = bplan.run(&benv);
    let interp = Plan::new(&w.g, &roots);
    for (b, env) in envs.iter().enumerate() {
        let oracle = interp.run(&w.g, env);
        for (r, want) in oracle.iter().enumerate() {
            let len = want.len();
            let chunk = batched[r].data()[b * len..(b + 1) * len].to_vec();
            let slice = Tensor::new(want.shape(), chunk);
            assert!(
                slice.allclose(want, 1e-9, 1e-11),
                "slice {b} of root {r} diverged from the per-request oracle, diff {}",
                slice.max_abs_diff(want)
            );
        }
    }
}

#[test]
fn direct_backend_never_touches_the_pool() {
    // even when asked for the Pooled ablation, the direct backend runs
    // in-arena: zero pool locks, a live arena, and bit-identity with
    // the planned cpu default
    let mut w = logistic_regression(48, 12);
    let grad = w.gradient();
    let direct = CompiledPlan::with_options(
        &w.g,
        &[w.loss, grad],
        true,
        EpilogueMode::default(),
        ExecMemory::Pooled,
        BackendKind::Direct,
        TraceMode::Off,
    );
    direct.validate_memory_plan();
    let got = direct.run(&w.env);
    for _ in 0..5 {
        let again = direct.run(&w.env);
        assert_eq!(again[0].data(), got[0].data());
        assert_eq!(again[1].data(), got[1].data());
    }
    let st = direct.pool_stats();
    assert_eq!(st.pool_locks, 0, "direct backend took the pool mutex: {:?}", st);
    assert!(st.arena_bytes > 0, "direct backend must carry an arena layout: {:?}", st);

    let want = CompiledPlan::new(&w.g, &[w.loss, grad]).run(&w.env);
    assert_eq!(got[0].data(), want[0].data());
    assert_eq!(got[1].data(), want[1].data());
}

#[test]
fn concurrent_direct_runs_are_isolated() {
    // one shared direct plan hammered from several threads: per-caller
    // arenas keep results bit-stable with no interference
    let mut w = logistic_regression(32, 8);
    let grad = w.gradient();
    let plan = CompiledPlan::with_backend(&w.g, &[w.loss, grad], BackendKind::Direct);
    let want = plan.run(&w.env);
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                for _ in 0..10 {
                    let got = plan.run(&w.env);
                    assert_eq!(got[0].data(), want[0].data(), "concurrent direct run diverged");
                    assert_eq!(got[1].data(), want[1].data());
                }
            });
        }
    });
}
