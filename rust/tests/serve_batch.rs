//! Differential pins for the serving layer's dynamic request batching:
//! the batched plan variant ([`tensorcalc::exec::batch_graph`] compiled
//! at `OptLevel::None`) must be **bit-identical** per batch slice to N
//! sequential runs of the base plan, and both must agree with the
//! interpreter oracle ([`tensorcalc::eval::Plan`]) on the *original*
//! (pre-optimizer) graph. Pinned across three workloads — the logistic
//! regression gradient, a neural-net Hessian, and a permuted-
//! contraction chain — at several batch sizes including the `bsz = 1`
//! ablation baseline.

use tensorcalc::coordinator::{Coordinator, EngineEntry};
use tensorcalc::einsum::EinSpec;
use tensorcalc::eval::{Env, Plan};
use tensorcalc::exec::{batch_graph, global_plan_cache, BackendKind, ExecMemory};
use tensorcalc::ir::{Elem, Graph, NodeId};
use tensorcalc::obs::TraceMode;
use tensorcalc::opt::{compact, optimize, OptLevel};
use tensorcalc::problems::{logistic_regression, neural_net};
use tensorcalc::tensor::Tensor;

/// Stack per-request tensors along a new leading axis (what the
/// coordinator worker does when it fuses a drained batch).
fn stack(ts: &[Tensor]) -> Tensor {
    let mut bshape = vec![ts.len()];
    bshape.extend_from_slice(ts[0].shape());
    let mut data = Vec::with_capacity(ts.len() * ts[0].len());
    for t in ts {
        data.extend_from_slice(t.data());
    }
    Tensor::new(&bshape, data)
}

/// The pin: canonicalise `g` exactly as `EngineEntry::compiled` does
/// (optimize + compact, then freeze at `OptLevel::None`), derive the
/// batched variant per bucket, and check every batch slice bitwise
/// against the sequential base plan and allclose against the
/// interpreter oracle on the original graph.
fn pin_batched_against_sequential(g: &Graph, roots: &[NodeId], seed0: u64, bszs: &[usize]) {
    let mut g2 = g.clone();
    let o = optimize(&mut g2, roots, OptLevel::Full);
    let (gc, croots) = compact(&g2, &o.roots);
    let base = global_plan_cache().get_or_compile_opts(
        &gc,
        &croots,
        OptLevel::None,
        ExecMemory::Planned,
        BackendKind::default(),
        TraceMode::Off,
    );
    let interp = Plan::new(g, roots);

    let vars: Vec<(String, Vec<usize>)> = g
        .var_names()
        .into_iter()
        .map(|n| {
            let id = g.var_id(&n).unwrap();
            (n, g.shape(id).to_vec())
        })
        .collect();

    for &bsz in bszs {
        let (bg, broots) = batch_graph(&gc, &croots, bsz);
        let bplan = global_plan_cache().get_or_compile_opts(
            &bg,
            &broots,
            OptLevel::None,
            ExecMemory::Planned,
            BackendKind::default(),
            TraceMode::Off,
        );

        let mut envs = Vec::new();
        for b in 0..bsz {
            let mut env = Env::new();
            for (i, (name, shape)) in vars.iter().enumerate() {
                let seed = seed0 + (b * vars.len() + i) as u64;
                env.insert(name, Tensor::randn(shape, seed).scale(0.5));
            }
            envs.push(env);
        }
        let mut benv = Env::new();
        for (name, _) in &vars {
            let ts: Vec<Tensor> =
                envs.iter().map(|e| e.get(name).unwrap().clone()).collect();
            benv.insert(name, stack(&ts));
        }

        let batched = bplan.run(&benv);
        for (b, env) in envs.iter().enumerate() {
            let seq = base.run(env);
            let oracle = interp.run(g, env);
            for (r, s) in seq.iter().enumerate() {
                let len = s.len();
                let slice = &batched[r].data()[b * len..(b + 1) * len];
                assert_eq!(
                    slice,
                    s.data(),
                    "bsz {}: slice {} of root {} not bit-identical to sequential run",
                    bsz,
                    b,
                    r
                );
                let st = Tensor::new(s.shape(), slice.to_vec());
                assert!(
                    st.allclose(&oracle[r], 1e-6, 1e-8),
                    "bsz {}: slice {} of root {} diverged from interpreter oracle, diff {}",
                    bsz,
                    b,
                    r,
                    st.max_abs_diff(&oracle[r])
                );
            }
        }
    }
}

/// Workload 1: logistic-regression loss + reverse gradient.
#[test]
fn logreg_gradient_batched_is_bit_identical() {
    let mut wl = logistic_regression(8, 4);
    let grad = wl.gradient();
    let roots = [wl.loss, grad];
    pin_batched_against_sequential(&wl.g, &roots, 100, &[1, 3, 4, 8]);
}

/// Workload 2: neural-net loss + reverse-over-reverse Hessian — deep
/// elementwise chains (ReLU, LogSumExp pullbacks) and many shared
/// subterms, the stress case for batchedness propagation through `Add`
/// with unbatched (delta/constant) operands.
#[test]
fn neural_net_hessian_batched_is_bit_identical() {
    let mut wl = neural_net(4, 2, 5);
    let h = wl.hessian();
    let roots = [wl.loss, h];
    pin_batched_against_sequential(&wl.g, &roots, 500, &[1, 3]);
}

/// Workload 3: permuted contractions — output axes reordered relative
/// to the operands ("ij,jk->ki" then "ki,ij->kj"), so the batch label
/// is threaded through specs whose outputs are not in operand order.
#[test]
fn permuted_contraction_batched_is_bit_identical() {
    let mut g = Graph::new();
    let a = g.var("A", &[4, 5]);
    let b = g.var("B", &[5, 3]);
    let c = g.mul(a, b, EinSpec::parse("ij,jk->ki"));
    let d = g.mul(c, a, EinSpec::parse("ki,ij->kj"));
    let e = g.elem(Elem::Exp, c);
    let one = g.constant(1.0, &[3, 5]);
    let s = g.add(d, one);
    pin_batched_against_sequential(&g, &[s, e], 900, &[1, 2, 5]);
}

/// End to end: the coordinator's batched serving path (drain → stack →
/// batched plan → split) answers every request with values that match
/// the interpreter oracle on the original graph.
#[test]
fn coordinator_batched_serving_matches_interpreter_oracle() {
    let mut wl = logistic_regression(6, 3);
    let grad = wl.gradient();
    let roots = vec![wl.loss, grad];
    let interp = Plan::new(&wl.g, &roots);

    let mut c = Coordinator::new(64);
    c.register_engine(
        "grad",
        EngineEntry::compiled(
            &wl.g,
            &roots,
            vec![
                ("X".into(), vec![6, 3]),
                ("y".into(), vec![6]),
                ("w".into(), vec![3]),
            ],
        ),
    );

    let mut pending = Vec::new();
    for s in 0..10u64 {
        let x = Tensor::randn(&[6, 3], 900 + s);
        let y = Tensor::randn(&[6], 950 + s).map(f64::signum);
        let wv = Tensor::randn(&[3], 990 + s);
        let rx = c.submit("grad", vec![x.clone(), y.clone(), wv.clone()]).unwrap();
        pending.push((x, y, wv, rx));
    }
    for (x, y, wv, rx) in pending {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.batch_size >= 1);
        let mut env = Env::new();
        env.insert("X", x);
        env.insert("y", y);
        env.insert("w", wv);
        let want = interp.run(&wl.g, &env);
        for (r, w_) in want.iter().enumerate() {
            assert!(
                resp.outputs[r].allclose(w_, 1e-8, 1e-10),
                "root {} diverged from oracle",
                r
            );
        }
    }
    c.shutdown();
}
