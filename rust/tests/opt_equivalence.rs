//! Differential verification of the graph-optimizer subsystem
//! (`rust/src/opt`): opt-on vs opt-off vs the interpreter oracle, on
//! random DAGs and on the three benchmark workloads' gradients and
//! Hessians, plus the public wiring (`eval_many`, `PlanCache`).
//!
//! Invariants pinned here:
//! * every `OptLevel` preserves values within the crate's existing
//!   tolerances (CSE is exact up to operand order; reassociation changes
//!   only the association and therefore only the last bits),
//! * optimisation is monotone in the stats it reports (`nodes_after ≤
//!   nodes_before`, `flops_after ≤ flops_before`) — the cost guard,
//! * `compact` (the dead-node sweep) never changes numerics,
//! * spec-canonicalization CSE actually merges relabelled / swapped
//!   duplicates, and reassociation actually re-associates a matrix
//!   chain.

use tensorcalc::autodiff::reverse::reverse_derivative;
use tensorcalc::einsum::EinSpec;
use tensorcalc::eval::{eval_many, eval_many_with, Env, Plan};
use tensorcalc::exec::CompiledPlan;
use tensorcalc::ir::{Elem, Graph, NodeId, Op};
use tensorcalc::opt::{compact, cost, optimize, OptLevel};
use tensorcalc::problems::{logistic_regression, matrix_factorization, neural_net};
use tensorcalc::tensor::{Tensor, XorShift};

/// Random scalar-expression DAG (same generator family as
/// tests/property.rs / tests/exec_equivalence.rs).
fn random_scalar_expr(rng: &mut XorShift, g: &mut Graph, depth: usize) -> NodeId {
    let x = g.var("x", &[4]);
    let a = g.var("A", &[4, 4]);
    let mut v = g.matvec(a, x);
    for _ in 0..depth {
        v = match rng.below(6) {
            0 => g.elem(Elem::Tanh, v),
            1 => g.elem(Elem::Sigmoid, v),
            2 => {
                let e = g.elem(Elem::Exp, v);
                let half = g.scale(e, 0.2);
                g.elem(Elem::Tanh, half)
            }
            3 => g.hadamard(v, x),
            4 => {
                let av = g.matvec(a, v);
                g.scale(av, 0.5)
            }
            _ => {
                let t = g.tmatvec(a, v);
                g.add(t, x)
            }
        };
    }
    let sq = g.elem(Elem::Square, v);
    g.sum_all(sq)
}

#[test]
fn prop_all_levels_match_interpreter_on_random_dags() {
    for seed in 0..25u64 {
        let mut rng = XorShift::new(4100 + seed);
        let mut g = Graph::new();
        let depth = 1 + (seed % 5) as usize;
        let f = random_scalar_expr(&mut rng, &mut g, depth);
        let x = g.var_id("x").unwrap();
        let grad = reverse_derivative(&mut g, f, &[x])[0];
        let mut env = Env::new();
        env.insert("x", Tensor::randn(&[4], seed + 1).scale(0.5));
        env.insert("A", Tensor::randn(&[4, 4], seed + 2).scale(0.5));
        let want = Plan::new(&g, &[f, grad]).run(&g, &env);
        for level in [OptLevel::None, OptLevel::Cse, OptLevel::Full] {
            let mut g2 = g.clone();
            let o = optimize(&mut g2, &[f, grad], level);
            assert!(
                o.stats.nodes_after <= o.stats.nodes_before,
                "seed {} {:?}: node count regressed: {}",
                seed,
                level,
                o.stats
            );
            assert!(
                o.stats.flops_after <= o.stats.flops_before,
                "seed {} {:?}: flop estimate regressed: {}",
                seed,
                level,
                o.stats
            );
            let got = CompiledPlan::new(&g2, &o.roots).run(&env);
            for (c, w) in got.iter().zip(&want) {
                assert!(
                    c.allclose(w, 1e-9, 1e-11),
                    "seed {} {:?}: optimized vs interpreter diff {}",
                    seed,
                    level,
                    c.max_abs_diff(w)
                );
            }
        }
    }
}

#[test]
fn workload_gradients_and_hessians_all_levels_match_interpreter() {
    for mut w in [
        logistic_regression(8, 4),
        matrix_factorization(6, 6, 2, false),
        neural_net(4, 3, 6),
    ] {
        let name = w.name;
        let grad = w.gradient();
        let h = w.hessian();
        let roots = [w.loss, grad, h];
        let want = Plan::new(&w.g, &roots).run(&w.g, &w.env);
        for level in [OptLevel::None, OptLevel::Cse, OptLevel::Full] {
            let mut g2 = w.g.clone();
            let o = optimize(&mut g2, &roots, level);
            assert!(o.stats.nodes_after <= o.stats.nodes_before, "{}: {}", name, o.stats);
            assert!(o.stats.flops_after <= o.stats.flops_before, "{}: {}", name, o.stats);
            let got = CompiledPlan::new(&g2, &o.roots).run(&w.env);
            for (c, wv) in got.iter().zip(&want) {
                assert!(
                    c.allclose(wv, 1e-8, 1e-10),
                    "{} {:?}: optimized executor vs interpreter diff {}",
                    name,
                    level,
                    c.max_abs_diff(wv)
                );
            }
            // the dead-node sweep must be invisible to the numerics
            let (gc, rc) = compact(&g2, &o.roots);
            let swept = CompiledPlan::new(&gc, &rc).run(&w.env);
            for (s, c) in swept.iter().zip(&got) {
                assert!(
                    s.allclose(c, 1e-12, 1e-14),
                    "{} {:?}: compaction changed values, diff {}",
                    name,
                    level,
                    s.max_abs_diff(c)
                );
            }
        }
    }
}

#[test]
fn fig3_hessians_report_joint_savings_for_full_roots() {
    // loss + gradient + Hessian jointly: the optimizer must never make
    // the joint DAG bigger, and the reported stats must be coherent
    for mut w in [
        logistic_regression(16, 8),
        matrix_factorization(8, 8, 3, false),
        neural_net(8, 3, 12),
    ] {
        let name = w.name;
        let grad = w.gradient();
        let h = w.hessian();
        let roots = [w.loss, grad, h];
        let mut g2 = w.g.clone();
        let o = optimize(&mut g2, &roots, OptLevel::Full);
        assert!(
            o.stats.nodes_after <= o.stats.nodes_before
                && o.stats.flops_after <= o.stats.flops_before,
            "{}: optimizer regressed: {}",
            name,
            o.stats
        );
        // sanity of the joint-cost accounting: the compacted graph holds
        // exactly the live nodes
        let (gc, rc) = compact(&g2, &o.roots);
        assert_eq!(gc.len(), g2.topo(&o.roots).len(), "{}", name);
        assert_eq!(cost::dag_flops(&gc, &rc), o.stats.flops_after, "{}", name);
    }
}

#[test]
fn cse_merges_relabelled_and_swapped_duplicates() {
    let mut g = Graph::new();
    let a = g.var("A", &[5, 6]);
    let x = g.var("x", &[6]);
    // three spellings of A·x: parsed labels, shifted labels, swapped
    let m1 = g.mul(a, x, EinSpec::parse("ij,j->i"));
    let m2 = g.mul(a, x, EinSpec::new(vec![11, 4], vec![4], vec![11]));
    let m3 = g.mul(x, a, EinSpec::parse("j,ij->i"));
    assert!(m1 != m2 && m2 != m3 && m1 != m3);
    let s12 = g.add(m1, m2);
    let s = g.add(s12, m3);
    let mut g2 = g.clone();
    let o = optimize(&mut g2, &[s], OptLevel::Cse);
    assert!(o.stats.cse_merged >= 2, "three spellings must merge: {}", o.stats);
    assert!(o.stats.nodes_after < o.stats.nodes_before, "{}", o.stats);
    let muls = g2
        .topo(&o.roots)
        .iter()
        .filter(|&&n| matches!(g2.op(n), Op::Mul(..)))
        .count();
    assert_eq!(muls, 1, "exactly one contraction must survive CSE");
    // numerics: 3·(A x)
    let mut env = Env::new();
    env.insert("A", Tensor::randn(&[5, 6], 1));
    env.insert("x", Tensor::randn(&[6], 2));
    let want = Plan::new(&g, &[s]).run(&g, &env);
    let got = Plan::new(&g2, &o.roots).run(&g2, &env);
    assert!(got[0].allclose(&want[0], 1e-12, 1e-13));
}

#[test]
fn matrix_chain_association_must_change() {
    // ((A·B)·C)·x on 24×24 matrices: right-to-left association is the
    // unique cheap order; the optimizer must find it
    let n = 24usize;
    let mut g = Graph::new();
    let a = g.var("A", &[n, n]);
    let b = g.var("B", &[n, n]);
    let c = g.var("C", &[n, n]);
    let x = g.var("x", &[n]);
    let ab = g.matmul(a, b);
    let abc = g.matmul(ab, c);
    let y = g.matvec(abc, x);
    let mut g2 = g.clone();
    let o = optimize(&mut g2, &[y], OptLevel::Full);
    assert!(o.stats.reassoc_rewritten >= 1, "{}", o.stats);
    // cheap order: three matvecs ≈ 3n², vs 2n³ + n² before
    let n3 = (n as u128).pow(3);
    assert!(o.stats.flops_before >= 2 * n3);
    assert!(
        o.stats.flops_after < o.stats.flops_before / 4,
        "association search missed the matvec chain: {}",
        o.stats
    );
    let mut env = Env::new();
    env.insert("A", Tensor::randn(&[n, n], 1).scale(0.3));
    env.insert("B", Tensor::randn(&[n, n], 2).scale(0.3));
    env.insert("C", Tensor::randn(&[n, n], 3).scale(0.3));
    env.insert("x", Tensor::randn(&[n], 4));
    let want = Plan::new(&g, &[y]).run(&g, &env);
    let got = Plan::new(&g2, &o.roots).run(&g2, &env);
    assert!(got[0].allclose(&want[0], 1e-9, 1e-11), "diff {}", got[0].max_abs_diff(&want[0]));
}

#[test]
fn eval_many_levels_agree_on_public_path() {
    // the public eval path runs the optimizer by default; the escape
    // hatch must agree within association tolerance
    let mut w = logistic_regression(12, 5);
    let grad = w.gradient();
    let h = w.hessian();
    let on = eval_many(&w.g, &[w.loss, grad, h], &w.env);
    let off = eval_many_with(&w.g, &[w.loss, grad, h], &w.env, OptLevel::None);
    for (a, b) in on.iter().zip(&off) {
        assert!(
            a.allclose(b, 1e-9, 1e-11),
            "opt-on vs opt-off diverged: diff {}",
            a.max_abs_diff(b)
        );
    }
}

#[test]
fn optimizer_handles_raw_delta_seeded_jacobians() {
    // unsimplified reverse-mode output: delta seeds, broadcast pullbacks,
    // permuted outputs — the optimizer must digest all of it
    for seed in 0..6u64 {
        let mut g = Graph::new();
        let a = g.var("A", &[3, 4]);
        let x = g.var("x", &[4]);
        let ax = g.matvec(a, x);
        let y = match seed % 3 {
            0 => g.elem(Elem::Exp, ax),
            1 => {
                let t = g.elem(Elem::Tanh, ax);
                g.hadamard(t, ax)
            }
            _ => {
                let s = g.elem(Elem::Sigmoid, ax);
                g.add(s, ax)
            }
        };
        let jac = reverse_derivative(&mut g, y, &[x, a]);
        let mut env = Env::new();
        env.insert("A", Tensor::randn(&[3, 4], 10 + seed));
        env.insert("x", Tensor::randn(&[4], 20 + seed));
        let want = Plan::new(&g, &jac).run(&g, &env);
        let mut g2 = g.clone();
        let o = optimize(&mut g2, &jac, OptLevel::Full);
        assert!(o.stats.nodes_after <= o.stats.nodes_before);
        assert!(o.stats.flops_after <= o.stats.flops_before);
        let got = CompiledPlan::new(&g2, &o.roots).run(&env);
        for (c, wv) in got.iter().zip(&want) {
            assert!(
                c.allclose(wv, 1e-9, 1e-11),
                "seed {}: diff {}",
                seed,
                c.max_abs_diff(wv)
            );
        }
    }
}
