#!/usr/bin/env sh
# Record the serving-layer load profile: run the serve_load open-loop
# bench (dynamic batching on vs the max_batch=1 ablation, at several
# offered rates) and write every row to BENCH_serve.json at the
# repository root, next to the exec-layer BENCH_exec.json.
#
# Usage:   scripts/bench_serve.sh
# Env:     BENCH_JSON  — override the output path (default BENCH_serve.json)
#          BENCH_SECS  — seconds per (rate, batch-cap) cell
#                        (default 0.3; CI's bench-smoke job uses 0.05 to
#                        keep the run short while still writing real rows)
set -eu
root=$(cd "$(dirname "$0")/.." && pwd)
out="${BENCH_JSON:-$root/BENCH_serve.json}"
cd "$root/rust"
BENCH_JSON="$out" BENCH_SECS="${BENCH_SECS:-0.3}" cargo bench --bench serve_load
echo "serve-load profile recorded at $out"
