#!/usr/bin/env python3
"""Validate the perf-trajectory and observability artifacts.

CI runs this right after `scripts/bench_baseline.sh` (which writes
`BENCH_exec.json`, schema `tensorcalc-bench-rows/v1`) and
`scripts/bench_serve.sh` (which writes `BENCH_serve.json`, schema
`tensorcalc-serve-load/v2`), so a bench refactor that silently changes
the row shape — renamed keys, stringified numbers, a dropped dimension —
fails the build instead of corrupting the downstream trajectory plots.

It also validates the PR 8 observability exports:

* Chrome trace-event JSON from `tensorcalc derive --trace json=PATH`
  (recognised by a top-level "traceEvents" array): every event needs
  str name/ph, int pid/tid, numeric ts, and complete ("X") events a
  non-negative dur; at least one complete event must be present.
* Prometheus text exposition from `tensorcalc serve --prom PATH`
  (recognised by a `.prom` / `.txt` extension or non-JSON content):
  each non-comment line must be `name[{labels}] value` with a float
  value, and at least one sample must be present.

Usage: check_bench_schema.py [FILE ...]

With no arguments, checks whichever of ./BENCH_exec.json and
./BENCH_serve.json exist (at least one must). The format is picked per
file from its content ("schema" / "traceEvents" field, else Prometheus
text). Stdlib only.
"""

import json
import numbers
import re
import sys

# field -> required type, per schema. bool is excluded from the numeric
# and int checks below (it subclasses int in Python).
EXEC_ROW = {
    "figure": str,
    "problem": str,
    "n": int,
    "mode": str,
    "median_secs": numbers.Real,
    "runs": int,
}

SERVE_ROW = {
    "entry": str,
    "cell": str,
    "max_batch": int,
    "offered_rps": numbers.Real,
    "achieved_rps": numbers.Real,
    "p50_secs": numbers.Real,
    "p99_secs": numbers.Real,
    "sent": int,
    "dropped": int,
    "shed": int,
    "expired": int,
    "deadline_ms": int,
}

SCHEMAS = {
    "tensorcalc-bench-rows/v1": EXEC_ROW,
    "tensorcalc-serve-load/v2": SERVE_ROW,
}

# figures the full ablation bench must always record — a refactor that
# silently drops one of these dimensions fails the build
REQUIRED_FIGURES = {
    "tensorcalc-bench-rows/v1": {"simd"},
}

# cells the serve-load bench must always record: "overload" is the
# robustness row (goodput + shed/expired under deadline pressure)
REQUIRED_CELLS = {
    "tensorcalc-serve-load/v2": {"overload"},
}

# counter families the coordinator's Prometheus exposition must carry
# once it is recognisably a tensorcalc dump — a metrics refactor that
# drops the robustness counters fails the build
REQUIRED_PROM_FAMILIES = {
    "tensorcalc_shed_total",
    "tensorcalc_expired_total",
    "tensorcalc_degraded_total",
    "tensorcalc_rejected_total",
}


def type_name(t):
    return getattr(t, "__name__", str(t))


def check_row(row, fields, where):
    errors = []
    if not isinstance(row, dict):
        return ["%s: row is %s, expected object" % (where, type(row).__name__)]
    for key, want in fields.items():
        if key not in row:
            errors.append("%s: missing field %r" % (where, key))
            continue
        val = row[key]
        if isinstance(val, bool) or not isinstance(val, want):
            errors.append(
                "%s: field %r is %s (%r), expected %s"
                % (where, key, type(val).__name__, val, type_name(want))
            )
    for key in row:
        if key not in fields:
            errors.append("%s: unknown field %r" % (where, key))
    return errors


# one Prometheus exposition sample: metric name, optional {labels},
# then a float (inf/nan allowed — histograms emit "+Inf" only in label
# values, which the label body swallows)
PROM_SAMPLE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})?\s+[-+]?"
    r"([0-9]*\.?[0-9]+([eE][-+]?[0-9]+)?|[iI]nf|[nN]a[nN])$"
)


def check_chrome_trace(doc, path):
    """Chrome trace-event JSON (the object-with-traceEvents format)."""
    errors = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["%s: 'traceEvents' is %s, expected array" % (path, type(events).__name__)]
    if not events:
        return ["%s: 'traceEvents' is empty — the trace recorded nothing" % path]
    complete = 0
    for i, ev in enumerate(events):
        where = "%s: traceEvents[%d]" % (path, i)
        if not isinstance(ev, dict):
            errors.append("%s: event is %s, expected object" % (where, type(ev).__name__))
            continue
        for key, want in (("name", str), ("ph", str), ("pid", int), ("tid", int)):
            val = ev.get(key)
            if isinstance(val, bool) or not isinstance(val, want):
                errors.append(
                    "%s: field %r is %s (%r), expected %s"
                    % (where, key, type(val).__name__, val, type_name(want))
                )
        if ev.get("ph") == "X":
            complete += 1
            for key in ("ts", "dur"):
                val = ev.get(key)
                if isinstance(val, bool) or not isinstance(val, numbers.Real):
                    errors.append("%s: complete event needs numeric %r, got %r" % (where, key, val))
                elif key == "dur" and val < 0:
                    errors.append("%s: negative dur %r" % (where, val))
    if complete == 0:
        errors.append("%s: no complete ('ph':'X') events — nothing was spanned" % path)
    if not errors:
        print("%s: OK (chrome-trace, %d events, %d complete)" % (path, len(events), complete))
    return errors


def check_prometheus(text, path):
    """Prometheus text exposition: comments + `name[{labels}] value`."""
    errors = []
    samples = 0
    families = set()
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if PROM_SAMPLE.match(line):
            samples += 1
            families.add(line.split("{", 1)[0].split(None, 1)[0])
        else:
            errors.append("%s:%d: malformed sample line %r" % (path, lineno, line))
    if samples == 0:
        errors.append("%s: no samples — the exposition is empty" % path)
    if any(f.startswith("tensorcalc_") for f in families):
        for fam in sorted(REQUIRED_PROM_FAMILIES - families):
            errors.append(
                "%s: required family %r missing (the robustness counters were dropped)"
                % (path, fam)
            )
    if not errors:
        print("%s: OK (prometheus, %d samples)" % (path, samples))
    return errors


def check_file(path):
    try:
        with open(path) as f:
            raw = f.read()
    except OSError as e:
        return ["%s: %s" % (path, e)]
    try:
        doc = json.loads(raw)
    except ValueError as e:
        # not JSON: the only non-JSON artifact is the Prometheus text dump
        if path.endswith(".json"):
            return ["%s: %s" % (path, e)]
        return check_prometheus(raw, path)
    if not isinstance(doc, dict):
        return ["%s: top level is %s, expected object" % (path, type(doc).__name__)]
    if "traceEvents" in doc:
        return check_chrome_trace(doc, path)
    schema = doc.get("schema")
    if schema not in SCHEMAS:
        return [
            "%s: unknown schema %r (expected one of %s)"
            % (path, schema, ", ".join(sorted(SCHEMAS)))
        ]
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return ["%s: 'rows' is %s, expected array" % (path, type(rows).__name__)]
    if not rows:
        return ["%s: 'rows' is empty — the bench recorded nothing" % path]
    errors = []
    fields = SCHEMAS[schema]
    for i, row in enumerate(rows):
        errors.extend(check_row(row, fields, "%s: rows[%d]" % (path, i)))
    have = {row.get("figure") for row in rows if isinstance(row, dict)}
    for fig in sorted(REQUIRED_FIGURES.get(schema, ())):
        if fig not in have:
            errors.append(
                "%s: required figure %r has no rows (the %s ablation was dropped)"
                % (path, fig, fig)
            )
    have_cells = {row.get("cell") for row in rows if isinstance(row, dict)}
    for cell in sorted(REQUIRED_CELLS.get(schema, ())):
        if cell not in have_cells:
            errors.append(
                "%s: required cell %r has no rows (the %s run was dropped)"
                % (path, cell, cell)
            )
    if not errors:
        print("%s: OK (%s, %d rows)" % (path, schema, len(rows)))
    return errors


def main(argv):
    paths = argv[1:]
    if not paths:
        import os

        paths = [p for p in ("BENCH_exec.json", "BENCH_serve.json") if os.path.exists(p)]
        if not paths:
            print("check_bench_schema.py: no BENCH_*.json found", file=sys.stderr)
            return 1
    errors = []
    for p in paths:
        errors.extend(check_file(p))
    for e in errors:
        print("check_bench_schema.py: %s" % e, file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
