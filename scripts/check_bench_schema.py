#!/usr/bin/env python3
"""Validate the perf-trajectory JSON artifacts against their schemas.

CI runs this right after `scripts/bench_baseline.sh` (which writes
`BENCH_exec.json`, schema `tensorcalc-bench-rows/v1`) and
`scripts/bench_serve.sh` (which writes `BENCH_serve.json`, schema
`tensorcalc-serve-load/v1`), so a bench refactor that silently changes
the row shape — renamed keys, stringified numbers, a dropped dimension —
fails the build instead of corrupting the downstream trajectory plots.

Usage: check_bench_schema.py [FILE ...]

With no arguments, checks whichever of ./BENCH_exec.json and
./BENCH_serve.json exist (at least one must). The schema is picked per
file from its "schema" field. Stdlib only.
"""

import json
import numbers
import sys

# field -> required type, per schema. bool is excluded from the numeric
# and int checks below (it subclasses int in Python).
EXEC_ROW = {
    "figure": str,
    "problem": str,
    "n": int,
    "mode": str,
    "median_secs": numbers.Real,
    "runs": int,
}

SERVE_ROW = {
    "entry": str,
    "max_batch": int,
    "offered_rps": numbers.Real,
    "achieved_rps": numbers.Real,
    "p50_secs": numbers.Real,
    "p99_secs": numbers.Real,
    "sent": int,
    "dropped": int,
}

SCHEMAS = {
    "tensorcalc-bench-rows/v1": EXEC_ROW,
    "tensorcalc-serve-load/v1": SERVE_ROW,
}


def type_name(t):
    return getattr(t, "__name__", str(t))


def check_row(row, fields, where):
    errors = []
    if not isinstance(row, dict):
        return ["%s: row is %s, expected object" % (where, type(row).__name__)]
    for key, want in fields.items():
        if key not in row:
            errors.append("%s: missing field %r" % (where, key))
            continue
        val = row[key]
        if isinstance(val, bool) or not isinstance(val, want):
            errors.append(
                "%s: field %r is %s (%r), expected %s"
                % (where, key, type(val).__name__, val, type_name(want))
            )
    for key in row:
        if key not in fields:
            errors.append("%s: unknown field %r" % (where, key))
    return errors


def check_file(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return ["%s: %s" % (path, e)]
    if not isinstance(doc, dict):
        return ["%s: top level is %s, expected object" % (path, type(doc).__name__)]
    schema = doc.get("schema")
    if schema not in SCHEMAS:
        return [
            "%s: unknown schema %r (expected one of %s)"
            % (path, schema, ", ".join(sorted(SCHEMAS)))
        ]
    rows = doc.get("rows")
    if not isinstance(rows, list):
        return ["%s: 'rows' is %s, expected array" % (path, type(rows).__name__)]
    if not rows:
        return ["%s: 'rows' is empty — the bench recorded nothing" % path]
    errors = []
    fields = SCHEMAS[schema]
    for i, row in enumerate(rows):
        errors.extend(check_row(row, fields, "%s: rows[%d]" % (path, i)))
    if not errors:
        print("%s: OK (%s, %d rows)" % (path, schema, len(rows)))
    return errors


def main(argv):
    paths = argv[1:]
    if not paths:
        import os

        paths = [p for p in ("BENCH_exec.json", "BENCH_serve.json") if os.path.exists(p)]
        if not paths:
            print("check_bench_schema.py: no BENCH_*.json found", file=sys.stderr)
            return 1
    errors = []
    for p in paths:
        errors.extend(check_file(p))
    for e in errors:
        print("check_bench_schema.py: %s" % e, file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
