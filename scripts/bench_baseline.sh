#!/usr/bin/env sh
# Record the exec-layer perf baseline: run the ablation_modes bench and
# write every measurement row to BENCH_exec.json at the repository root,
# so later PRs can diff their numbers against this trajectory file.
#
# Usage:   scripts/bench_baseline.sh
# Env:     BENCH_JSON  — override the output path (default BENCH_exec.json)
#          BENCH_SECS  — not yet wired; edit `secs` in the bench source
set -eu
root=$(cd "$(dirname "$0")/.." && pwd)
out="${BENCH_JSON:-$root/BENCH_exec.json}"
cd "$root/rust"
BENCH_JSON="$out" cargo bench --bench ablation_modes
echo "perf trajectory recorded at $out"
