#!/usr/bin/env sh
# Record the exec-layer perf baseline: run the ablation_modes bench and
# write every measurement row to BENCH_exec.json at the repository root,
# so later PRs can diff their numbers against this trajectory file.
#
# Usage:   scripts/bench_baseline.sh
# Env:     BENCH_JSON  — override the output path (default BENCH_exec.json)
#          BENCH_SECS  — per-measurement time budget in seconds
#                        (default 0.3; CI's bench-smoke job uses 0.05 to
#                        keep the run short while still writing real rows)
set -eu
root=$(cd "$(dirname "$0")/.." && pwd)
out="${BENCH_JSON:-$root/BENCH_exec.json}"
cd "$root/rust"
BENCH_JSON="$out" BENCH_SECS="${BENCH_SECS:-0.3}" cargo bench --bench ablation_modes
echo "perf trajectory recorded at $out"
