"""AOT compilation: lower every Layer-2 entry point to HLO **text** and
write it under artifacts/ together with a manifest the Rust runtime reads.

HLO text — never ``lowered.compiler_ir(...).serialize()`` or proto bytes:
jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage: ``python -m compile.aot [--out-dir ../artifacts]``
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Fixed AOT shapes (documented in DESIGN.md §5).
LOGREG_M, LOGREG_N = 256, 128
MATFAC_M, MATFAC_N, MATFAC_K = 128, 128, 5
MLP_BATCH, MLP_WIDTH, MLP_LAYERS = 64, 32, 10


def to_hlo_text(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entries():
    """name -> (callable, example specs, output names)."""
    m, n = LOGREG_M, LOGREG_N
    fm, fn_, fk = MATFAC_M, MATFAC_N, MATFAC_K
    b, w, layers = MLP_BATCH, MLP_WIDTH, MLP_LAYERS

    def mlp_vg(X, Y, *ws):
        return model.mlp_val_grad_w1(list(ws), X, Y)

    mlp_args = [spec(b, w), spec(b, w)] + [spec(w, w)] * layers

    return {
        "logreg_val_grad": (
            model.logreg_val_grad,
            [spec(n), spec(m, n), spec(m)],
            ["loss", "grad"],
        ),
        "logreg_hess": (
            model.logreg_hess,
            [spec(n), spec(m, n), spec(m)],
            ["hessian"],
        ),
        "logreg_hess_jax": (
            model.logreg_hess_jax,
            [spec(n), spec(m, n), spec(m)],
            ["hessian"],
        ),
        "matfac_val_grad": (
            model.matfac_val_grad,
            [spec(fm, fk), spec(fm, fn_), spec(fn_, fk)],
            ["loss", "grad"],
        ),
        "matfac_hess_core": (
            model.matfac_hess_core,
            [spec(fn_, fk)],
            ["core"],
        ),
        "mlp_val_grad": (mlp_vg, mlp_args, ["loss", "grad_w1"]),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--out", default=None, help="unused compat flag")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"dtype": "f32", "entries": {}}
    for name, (fn, specs, outs) in entries().items():
        text = to_hlo_text(fn, *specs)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(s.shape) for s in specs],
            "outputs": outs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"wrote {path} ({len(text)} chars)")

    # example input/output bundle for the Rust cross-check test — raw
    # little-endian f32 files (the offline Rust build has no npz reader)
    check_dir = os.path.join(out_dir, "check")
    os.makedirs(check_dir, exist_ok=True)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((LOGREG_M, LOGREG_N)).astype(np.float32)
    y = np.sign(rng.standard_normal(LOGREG_M)).astype(np.float32)
    w = (0.1 * rng.standard_normal(LOGREG_N)).astype(np.float32)
    val, grad = model.logreg_val_grad(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y))
    hess = model.logreg_hess(jnp.asarray(w), jnp.asarray(x), jnp.asarray(y))
    for name, arr in [
        ("X", x), ("y", y), ("w", w),
        ("loss", np.asarray(val, dtype=np.float32)),
        ("grad", np.asarray(grad, dtype=np.float32)),
        ("hess", np.asarray(hess, dtype=np.float32)),
    ]:
        arr.astype("<f4").tofile(os.path.join(check_dir, f"logreg_{name}.f32"))

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # plain-text manifest for the (serde-less) Rust runtime:
    #   name<TAB>file<TAB>shape;shape;...<TAB>out1,out2
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for name, e in manifest["entries"].items():
            shapes = ";".join(",".join(str(d) for d in s) for s in e["inputs"])
            f.write(f"{name}\t{e['file']}\t{shapes}\t{','.join(e['outputs'])}\n")
    print(f"wrote {out_dir}/manifest.json + manifest.txt")


if __name__ == "__main__":
    main()
