"""Layer-2 JAX models: the paper's three benchmark workloads with their
gradients and (compressed) Hessians, written so the compute hot-spot runs
through the Layer-1 Pallas kernels.

These functions exist for two purposes:
1. build-time correctness (pytest checks them against jax.grad /
   jax.hessian), and
2. AOT lowering (aot.py) to HLO text that the Rust runtime loads via
   PJRT — the "deep-learning framework" comparison path of Figures 2/3,
   executed from the Rust coordinator with Python off the request path.

The closed-form derivative expressions below are exactly what the Rust
tensor-calculus engine derives symbolically; the cross-layer integration
test checks Rust-engine numerics against these artifacts.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul_tn, xt_diag_x


# ---------------------------------------------------------------- logreg

def logreg_loss(w, X, y):
    """f(w) = Σ_i log(exp(−y_i·(X_i w)) + 1)."""
    z = X @ w
    return jnp.sum(jnp.logaddexp(0.0, -y * z))


def logreg_val_grad(w, X, y):
    """Loss and gradient, closed form: ∇f = Xᵀ(−y ⊙ σ(−y⊙z))."""
    z = X @ w
    t = -y * z
    val = jnp.sum(jnp.logaddexp(0.0, t))
    s = jax.nn.sigmoid(t)
    grad = X.T @ (-y * s)
    return val, grad


def logreg_hess(w, X, y):
    """Compressed Hessian H = Xᵀ·diag(v)·X with v = σ(t)(1−σ(t)), t=−y⊙z.

    The diag(v) factor is fused inside the Pallas kernel — the paper's
    cross-country ordering (vectors merge before the matrix products).
    """
    z = X @ w
    t = -y * z
    s = jax.nn.sigmoid(t)
    v = s * (1.0 - s)  # y² = 1
    return xt_diag_x(X, v)


def logreg_hess_jax(w, X, y):
    """The real-JAX comparator: jax.hessian of the loss."""
    return jax.hessian(logreg_loss)(w, X, y)


# ---------------------------------------------------------------- matfac

def matfac_loss(U, T, V):
    """f(U) = ‖T − U Vᵀ‖²."""
    r = T - U @ V.T
    return jnp.sum(r * r)


def matfac_val_grad(U, T, V):
    """Loss and gradient: ∇_U f = −2(T − UVᵀ)V."""
    r = T - U @ V.T
    return jnp.sum(r * r), -2.0 * r @ V


def matfac_hess_core(V):
    """The compressed Hessian core 2·VᵀV (full H = core ⊗ 𝕀, §3.3),
    via the Pallas blocked AᵀB kernel."""
    return 2.0 * matmul_tn(V, V)


# ---------------------------------------------------------------- mlp

def mlp_logits(ws, X):
    """`len(ws)` dense layers, ReLU between, last layer linear."""
    h = X
    for i, w in enumerate(ws):
        z = h @ w
        h = jax.nn.relu(z) if i + 1 < len(ws) else z
    return h


def mlp_loss(ws, X, Y):
    """Softmax cross-entropy against one-hot Y (summed, like the paper)."""
    z = mlp_logits(ws, X)
    lse = jax.scipy.special.logsumexp(z, axis=-1)
    return jnp.sum(lse) - jnp.sum(Y * z)


def mlp_val_grad_w1(ws, X, Y):
    """Loss and gradient w.r.t. the first layer's weights (the layer the
    paper reports Hessian times for)."""
    def f(w1):
        return mlp_loss([w1] + list(ws[1:]), X, Y)
    val, g = jax.value_and_grad(f)(ws[0])
    return val, g
