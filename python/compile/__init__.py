"""Build-time Python package: Layer-2 JAX models + Layer-1 Pallas kernels
and the AOT lowering to HLO-text artifacts. Never imported at runtime."""
