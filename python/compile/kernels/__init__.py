"""Layer-1 Pallas kernels (build-time only)."""

from .contraction import matmul_tn, xt_diag_x
from .ref import matmul_tn_ref, xt_diag_x_ref

__all__ = ["xt_diag_x", "matmul_tn", "xt_diag_x_ref", "matmul_tn_ref"]
