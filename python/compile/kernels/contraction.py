"""Layer-1 Pallas kernels: the compute hot-spots of the paper's derivative
expressions.

Two kernels cover the benchmark workloads:

* ``xt_diag_x`` — the fused ``Xᵀ·diag(v)·X`` contraction, the core of the
  logistic-regression compressed Hessian and the archetype of the paper's
  cross-country product ``B·diag(u)·diag(v)·A`` (Example 7): the
  element-wise (vector) factor is folded into the tile of ``X`` *before*
  the MXU matmul, so ``diag(v)`` (an m×m matrix) is never materialised.
* ``matmul_tn`` — blocked ``AᵀB``, used for the matrix-factorization
  Hessian core ``2·VᵀV``.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper ran NumPy/CuPy;
here each kernel streams row-tiles of the data matrix HBM→VMEM via
BlockSpec, multiplies by the broadcast vector tile in the VPU, and feeds
the MXU with a ``(bm, n)ᵀ × (bm, n)`` contraction accumulated across grid
steps in the output tile. ``interpret=True`` everywhere: the CPU PJRT
client cannot execute Mosaic custom-calls, and correctness is what the
build-time pytest checks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xt_diag_x_kernel(x_ref, v_ref, o_ref):
    """One grid step: o += (x·v[:,None])ᵀ @ x over a row tile."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]  # [bm, n] tile in VMEM
    vb = v_ref[...]  # [bm]
    xv = xb * vb[:, None]  # fold diag(v) in the VPU — no m×m matrix
    o_ref[...] += jnp.dot(xv.T, xb, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m",))
def xt_diag_x(x, v, block_m=128):
    """``Xᵀ·diag(v)·X`` for ``X: [m, n]``, ``v: [m]`` → ``[n, n]``.

    ``m`` must be divisible by ``block_m`` (pad upstream if needed; the
    AOT shapes are chosen aligned).
    """
    m, n = x.shape
    bm = min(block_m, m)
    assert m % bm == 0, f"m={m} not divisible by block_m={bm}"
    grid = (m // bm,)
    return pl.pallas_call(
        _xt_diag_x_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        interpret=True,
    )(x, v)


def _matmul_tn_kernel(a_ref, b_ref, o_ref):
    """One grid step: o += aᵀ @ b over a row tile."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...].T, b_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m",))
def matmul_tn(a, b, block_m=128):
    """``AᵀB`` for ``A: [m, k]``, ``B: [m, n]`` → ``[k, n]`` (row-blocked)."""
    m, k = a.shape
    m2, n = b.shape
    assert m == m2, f"row mismatch {m} vs {m2}"
    bm = min(block_m, m)
    assert m % bm == 0, f"m={m} not divisible by block_m={bm}"
    grid = (m // bm,)
    return pl.pallas_call(
        _matmul_tn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, n), a.dtype),
        interpret=True,
    )(a, b)
