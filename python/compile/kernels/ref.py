"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
reference (pytest compares kernel output against these)."""

import jax.numpy as jnp


def xt_diag_x_ref(x, v):
    """``Xᵀ·diag(v)·X`` by plain einsum."""
    return jnp.einsum("ij,i,ik->jk", x, v, x)


def matmul_tn_ref(a, b):
    """``AᵀB`` by plain einsum."""
    return jnp.einsum("ij,ik->jk", a, b)
