"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.
Hypothesis sweeps shapes and dtypes — the core correctness signal for the
kernel layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional in the offline environment: the parametrized
# tests below still run without it, only the randomized sweeps skip.
try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAS_HYPOTHESIS = False

    def given(**_kwargs):  # type: ignore[misc]
        def deco(_fn):
            return pytest.mark.skip(reason="hypothesis not installed")(_fn)

        return deco

    def settings(**_kwargs):  # type: ignore[misc]
        def deco(fn):
            return fn

        return deco

    class _St:
        @staticmethod
        def integers(**kwargs):
            return kwargs

        @staticmethod
        def sampled_from(values):
            return values

    st = _St()

from compile.kernels import matmul_tn, matmul_tn_ref, xt_diag_x, xt_diag_x_ref

jax.config.update("jax_enable_x64", True)


def rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4), jnp.float64: dict(rtol=1e-9, atol=1e-9)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("m,n,bm", [(8, 4, 8), (64, 16, 32), (128, 32, 128), (256, 8, 64)])
def test_xt_diag_x_matches_ref(dtype, m, n, bm):
    x = rand((m, n), dtype, 1)
    v = rand((m,), dtype, 2)
    got = xt_diag_x(x, v, block_m=bm)
    want = xt_diag_x_ref(x, v)
    np.testing.assert_allclose(got, want, **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("m,k,n,bm", [(8, 3, 5, 8), (64, 16, 16, 32), (128, 5, 5, 128)])
def test_matmul_tn_matches_ref(dtype, m, k, n, bm):
    a = rand((m, k), dtype, 3)
    b = rand((m, n), dtype, 4)
    got = matmul_tn(a, b, block_m=bm)
    want = matmul_tn_ref(a, b)
    np.testing.assert_allclose(got, want, **TOL[dtype])


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=6),
    bm=st.sampled_from([8, 16, 32]),
    n=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_xt_diag_x_hypothesis_sweep(blocks, bm, n, seed):
    m = blocks * bm
    x = rand((m, n), jnp.float64, seed)
    v = rand((m,), jnp.float64, seed + 1)
    got = xt_diag_x(x, v, block_m=bm)
    want = xt_diag_x_ref(x, v)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=5),
    bm=st.sampled_from([8, 16]),
    k=st.integers(min_value=1, max_value=12),
    n=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_matmul_tn_hypothesis_sweep(blocks, bm, k, n, seed):
    m = blocks * bm
    a = rand((m, k), jnp.float64, seed)
    b = rand((m, n), jnp.float64, seed + 1)
    got = matmul_tn(a, b, block_m=bm)
    want = matmul_tn_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_xt_diag_x_psd_when_v_nonnegative():
    x = rand((64, 8), jnp.float64, 9)
    v = jnp.abs(rand((64,), jnp.float64, 10))
    h = np.asarray(xt_diag_x(x, v, block_m=32))
    eig = np.linalg.eigvalsh(h)
    assert eig.min() > -1e-10


def test_block_size_must_divide_rows():
    x = rand((10, 4), jnp.float64, 11)
    v = rand((10,), jnp.float64, 12)
    with pytest.raises(AssertionError):
        xt_diag_x(x, v, block_m=4)


def test_single_block_fast_path():
    # block_m >= m collapses to a single grid step
    x = rand((16, 4), jnp.float64, 13)
    v = rand((16,), jnp.float64, 14)
    got = xt_diag_x(x, v, block_m=128)
    np.testing.assert_allclose(got, xt_diag_x_ref(x, v), rtol=1e-9, atol=1e-9)
