"""Layer-2 correctness: the closed-form derivative expressions in model.py
against jax.grad / jax.hessian."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_enable_x64", True)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(scale * rng.standard_normal(shape))


@pytest.fixture
def logreg_data():
    m, n = 32, 8
    X = rand((m, n), 0)
    y = jnp.sign(rand((m,), 1))
    w = rand((n,), 2, 0.1)
    return w, X, y


def test_logreg_grad_matches_jax(logreg_data):
    w, X, y = logreg_data
    _, g = model.logreg_val_grad(w, X, y)
    gt = jax.grad(model.logreg_loss)(w, X, y)
    np.testing.assert_allclose(g, gt, rtol=1e-9, atol=1e-10)


def test_logreg_hess_matches_jax(logreg_data):
    w, X, y = logreg_data
    h = model.logreg_hess(w, X, y)
    ht = jax.hessian(model.logreg_loss)(w, X, y)
    np.testing.assert_allclose(h, ht, rtol=1e-8, atol=1e-9)


def test_logreg_hess_symmetric_psd(logreg_data):
    w, X, y = logreg_data
    h = np.asarray(model.logreg_hess(w, X, y))
    np.testing.assert_allclose(h, h.T, rtol=1e-12, atol=1e-12)
    assert np.linalg.eigvalsh(h).min() > -1e-10


def test_matfac_grad_matches_jax():
    m, n, k = 12, 10, 3
    U, T, V = rand((m, k), 3), rand((m, n), 4), rand((n, k), 5)
    _, g = model.matfac_val_grad(U, T, V)
    gt = jax.grad(model.matfac_loss)(U, T, V)
    np.testing.assert_allclose(g, gt, rtol=1e-9, atol=1e-10)


def test_matfac_hess_core_is_compressed_hessian():
    # full Hessian H[i,j,k,l] = core[j,l]·δ_ik
    m, n, k = 8, 8, 2
    U, T, V = rand((m, k), 6), rand((m, n), 7), rand((n, k), 8)
    core = np.asarray(model.matfac_hess_core(V))
    H = np.asarray(jax.hessian(model.matfac_loss)(U, T, V))  # [m,k,m,k]
    for i in range(m):
        for kk in range(m):
            blk = H[i, :, kk, :]
            want = core if i == kk else np.zeros_like(core)
            np.testing.assert_allclose(blk, want, rtol=1e-8, atol=1e-8)


def test_mlp_grad_matches_jax():
    b, w, layers = 8, 6, 4
    X, Y = rand((b, w), 9), jnp.asarray(np.eye(w)[np.random.default_rng(1).integers(0, w, b)])
    ws = [rand((w, w), 10 + i, 1 / np.sqrt(w)) for i in range(layers)]
    _, g = model.mlp_val_grad_w1(ws, X, Y)
    gt = jax.grad(lambda w1: model.mlp_loss([w1] + ws[1:], X, Y))(ws[0])
    np.testing.assert_allclose(g, gt, rtol=1e-9, atol=1e-10)


def test_mlp_loss_nonnegative():
    b, w = 8, 6
    X = rand((b, w), 20)
    Y = jnp.asarray(np.eye(w)[np.random.default_rng(2).integers(0, w, b)])
    ws = [rand((w, w), 30 + i, 1 / np.sqrt(w)) for i in range(3)]
    assert float(model.mlp_loss(ws, X, Y)) > 0.0


def test_aot_entries_lower_to_hlo_text():
    # every registered entry must lower; HLO text must name an ENTRY
    from compile import aot
    for name, (fn, specs, _) in aot.entries().items():
        text = aot.to_hlo_text(fn, *specs)
        assert "ENTRY" in text, name
        assert len(text) > 100, name
